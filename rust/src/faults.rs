//! Deterministic fault injection for the serving tier.
//!
//! A [`FaultPlan`] is a seeded schedule of faults to fire at **named
//! injection sites** threaded through the serving stack. The plan is
//! immutable after construction; per-rule hit counters make firing
//! decisions deterministic for a given (plan, call sequence), so a
//! chaos test that replays the same request schedule sees the same
//! faults — the substrate the supervision layer is tested against.
//!
//! ## Sites
//!
//! | site      | where the check runs                                  |
//! |-----------|-------------------------------------------------------|
//! | `compute` | shard dispatcher, after a batch is popped, before the |
//! |           | engine call — a `panic` here kills the dispatcher     |
//! |           | thread exactly like a kernel panic would              |
//! | `submit`  | [`super::coordinator::SpmvService::submit`], before   |
//! |           | the queue push — a `delay` here models a queue stall  |
//! | `recv`    | the service receive path, after a response arrives —  |
//! |           | a `delay` here models a slow client-side link         |
//! | `worker`  | inside [`crate::parallel::WorkerPool`] task execution |
//! |           | (global plan only) — a `panic` here exercises the     |
//! |           | pool's catch/propagate/stay-usable contract           |
//! | `io_write`| [`crate::util::durable::save_state`], before the      |
//! |           | atomic write — a `torn{at}` here leaves a truncated   |
//! |           | file at the destination, the crash-consistency        |
//! |           | substrate                                             |
//! | `io_read` | [`crate::util::durable::read_state`], before the file |
//! |           | is opened — `panic`/`delay` model a failing or slow   |
//! |           | state disk                                            |
//!
//! Every site check is always compiled (no feature gate); with no
//! plan installed it is one `Option` test — cheap enough for the
//! serving hot path (the `SPC5_ABLATION=chaos` bench section measures
//! exactly this overhead).
//!
//! ## `SPC5_FAULTS` grammar
//!
//! Clauses separated by `;`, each `ACTION@SITE[:key=value,...]`:
//!
//! ```text
//! panic@compute:shard=1,nth=3
//! delay@recv:ms=5,every=2
//! panic@compute:shard=0,every=1,times=4;delay@submit:ms=1,prob=0.25
//! torn@io_write:at=16,nth=0
//! ```
//!
//! - `ACTION` — `panic`, `delay` (`delay` takes `ms=N`, default 1), or
//!   `torn` (`io_write` only; takes `at=N` bytes, default 0 — the save
//!   is cut after `N` bytes of the framed output, emulating a crash
//!   mid-write of a non-atomic writer).
//! - `SITE` — `compute`, `submit`, `recv`, `worker`, `io_write`,
//!   `io_read`.
//! - `shard=N` — only fire on shard `N` (for `worker`: worker index).
//! - `request=N` — only fire on request id `N` (`compute`/`submit`).
//! - `nth=N` — fire on the N-th matching hit only (0-based).
//! - `every=N` — fire on every N-th matching hit (the N-th, 2N-th, …).
//! - `prob=F` — fire with probability `F`, decided by a seeded hash
//!   of (plan seed, rule index, hit index): deterministic and
//!   lock-free.
//! - `times=N` — cap total fires of this rule at `N`.
//!
//! Without `nth`/`every`/`prob`, a rule fires on every matching hit
//! (subject to `times`). The plan seed comes from `SPC5_FAULTS_SEED`
//! (default `0x5eed`).
//!
//! ## Installation
//!
//! The serving constructors take an explicit `Option<Arc<FaultPlan>>`
//! (test-driven chaos) and fall back to the process-global plan
//! parsed once from the environment ([`global`]). Tests that need a
//! global plan (the `worker` site) install one through the
//! [`InstallGuard`] RAII handle so concurrent tests do not fight over
//! process state.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, RwLock};
use std::time::Duration;

/// A named injection site, with the identity of the call that hit it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Site {
    /// Shard dispatcher about to run the kernel for a batch whose
    /// first member is `request`.
    Compute { shard: usize, request: u64 },
    /// Service submit path, before the queue push.
    Submit { shard: usize, request: u64 },
    /// Service receive path, response in hand.
    Recv { shard: usize },
    /// Worker-pool task body on worker `worker`.
    Worker { worker: usize },
    /// Durable state save path, before the atomic write.
    IoWrite,
    /// Durable state load path, before the file is opened.
    IoRead,
}

impl Site {
    fn kind(&self) -> SiteKind {
        match self {
            Site::Compute { .. } => SiteKind::Compute,
            Site::Submit { .. } => SiteKind::Submit,
            Site::Recv { .. } => SiteKind::Recv,
            Site::Worker { .. } => SiteKind::Worker,
            Site::IoWrite => SiteKind::IoWrite,
            Site::IoRead => SiteKind::IoRead,
        }
    }

    /// The shard filter key: shard index for service sites, worker
    /// index for the pool site. IO sites carry no shard identity.
    fn shard_key(&self) -> usize {
        match *self {
            Site::Compute { shard, .. }
            | Site::Submit { shard, .. }
            | Site::Recv { shard } => shard,
            Site::Worker { worker } => worker,
            Site::IoWrite | Site::IoRead => 0,
        }
    }

    fn request_key(&self) -> Option<u64> {
        match *self {
            Site::Compute { request, .. } | Site::Submit { request, .. } => {
                Some(request)
            }
            Site::Recv { .. }
            | Site::Worker { .. }
            | Site::IoWrite
            | Site::IoRead => None,
        }
    }
}

/// Site class, as named in the `SPC5_FAULTS` grammar.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SiteKind {
    Compute,
    Submit,
    Recv,
    Worker,
    IoWrite,
    IoRead,
}

impl SiteKind {
    fn parse(s: &str) -> Result<SiteKind, String> {
        match s {
            "compute" => Ok(SiteKind::Compute),
            "submit" => Ok(SiteKind::Submit),
            "recv" => Ok(SiteKind::Recv),
            "worker" => Ok(SiteKind::Worker),
            "io_write" => Ok(SiteKind::IoWrite),
            "io_read" => Ok(SiteKind::IoRead),
            other => Err(format!(
                "unknown fault site {other:?} \
                 (compute|submit|recv|worker|io_write|io_read)"
            )),
        }
    }

    fn name(&self) -> &'static str {
        match self {
            SiteKind::Compute => "compute",
            SiteKind::Submit => "submit",
            SiteKind::Recv => "recv",
            SiteKind::Worker => "worker",
            SiteKind::IoWrite => "io_write",
            SiteKind::IoRead => "io_read",
        }
    }
}

/// What a firing rule does at its site.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Action {
    /// `panic!` — at the `compute` site this kills the dispatcher
    /// thread, at `worker` it exercises the pool's panic contract.
    Panic,
    /// Sleep for the given duration (queue stall / recv delay).
    Delay(Duration),
    /// Cut the save after `at` bytes, leaving a truncated destination
    /// file (only meaningful at `io_write`; the writer cooperates via
    /// [`FaultPlan::check_io`]).
    Torn { at: u64 },
}

/// One clause of a plan: a site matcher plus a trigger and an action.
#[derive(Debug)]
pub struct FaultRule {
    pub site: SiteKind,
    /// Only fire on this shard (worker index for `worker` sites).
    pub shard: Option<usize>,
    /// Only fire on this request id (`compute`/`submit` sites).
    pub request: Option<u64>,
    /// Fire on exactly the N-th matching hit (0-based).
    pub nth: Option<u64>,
    /// Fire on every N-th matching hit.
    pub every: Option<u64>,
    /// Fire with this probability per matching hit (seeded hash).
    pub prob: Option<f64>,
    /// Cap on total fires.
    pub times: Option<u64>,
    pub action: Action,
    /// Matching hits seen so far (drives `nth`/`every`/`prob`).
    hits: AtomicU64,
    /// Fires so far (drives `times`).
    fires: AtomicU64,
}

impl FaultRule {
    /// A rule that fires `action` at every matching hit of `site`.
    pub fn new(site: SiteKind, action: Action) -> FaultRule {
        FaultRule {
            site,
            shard: None,
            request: None,
            nth: None,
            every: None,
            prob: None,
            times: None,
            action,
            hits: AtomicU64::new(0),
            fires: AtomicU64::new(0),
        }
    }

    pub fn shard(mut self, shard: usize) -> FaultRule {
        self.shard = Some(shard);
        self
    }

    pub fn request(mut self, id: u64) -> FaultRule {
        self.request = Some(id);
        self
    }

    pub fn nth(mut self, n: u64) -> FaultRule {
        self.nth = Some(n);
        self
    }

    pub fn every(mut self, k: u64) -> FaultRule {
        assert!(k >= 1, "every=0 never fires");
        self.every = Some(k);
        self
    }

    pub fn prob(mut self, p: f64) -> FaultRule {
        assert!((0.0..=1.0).contains(&p), "prob must be in [0, 1]");
        self.prob = Some(p);
        self
    }

    pub fn times(mut self, n: u64) -> FaultRule {
        self.times = Some(n);
        self
    }

    fn matches(&self, site: &Site) -> bool {
        self.site == site.kind()
            && self.shard.map_or(true, |s| s == site.shard_key())
            && self
                .request
                .map_or(true, |r| Some(r) == site.request_key())
    }

    /// Consumes one matching hit and decides whether to fire.
    fn should_fire(&self, seed: u64, rule_idx: usize) -> bool {
        let hit = self.hits.fetch_add(1, Ordering::Relaxed);
        let triggered = if let Some(n) = self.nth {
            hit == n
        } else if let Some(k) = self.every {
            (hit + 1) % k == 0
        } else if let Some(p) = self.prob {
            // Stateless per-hit coin: a splitmix-style hash of
            // (seed, rule, hit) mapped to [0, 1). Deterministic under
            // concurrency (no shared RNG stream to race on).
            hash_unit(seed ^ mix(rule_idx as u64) ^ mix(hit)) < p
        } else {
            true
        };
        if !triggered {
            return false;
        }
        if let Some(cap) = self.times {
            // Reserve a fire slot; back out if over the cap.
            let prev = self.fires.fetch_add(1, Ordering::Relaxed);
            if prev >= cap {
                return false;
            }
            true
        } else {
            self.fires.fetch_add(1, Ordering::Relaxed);
            true
        }
    }
}

fn mix(mut z: u64) -> u64 {
    // splitmix64 finalizer: full-avalanche 64-bit mix.
    z = z.wrapping_add(0x9e3779b97f4a7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

fn hash_unit(z: u64) -> f64 {
    (mix(z) >> 11) as f64 / (1u64 << 53) as f64
}

/// A seeded, immutable schedule of fault rules (see module docs).
#[derive(Debug)]
pub struct FaultPlan {
    rules: Vec<FaultRule>,
    seed: u64,
    total_fires: AtomicU64,
}

/// Default seed when `SPC5_FAULTS_SEED` is absent.
pub const DEFAULT_SEED: u64 = 0x5eed;

impl FaultPlan {
    /// A plan from explicit rules (test construction).
    pub fn new(rules: Vec<FaultRule>, seed: u64) -> FaultPlan {
        FaultPlan { rules, seed, total_fires: AtomicU64::new(0) }
    }

    /// Parses the `SPC5_FAULTS` grammar (see module docs).
    pub fn parse(spec: &str, seed: u64) -> Result<FaultPlan, String> {
        let mut rules = Vec::new();
        for clause in spec.split(';') {
            let clause = clause.trim();
            if clause.is_empty() {
                continue;
            }
            let (action_s, rest) = clause
                .split_once('@')
                .ok_or_else(|| format!("clause {clause:?}: missing '@'"))?;
            let (site_s, kv) = match rest.split_once(':') {
                Some((s, kv)) => (s, kv),
                None => (rest, ""),
            };
            let site = SiteKind::parse(site_s.trim())?;
            let mut rule = match action_s.trim() {
                "panic" => FaultRule::new(site, Action::Panic),
                "delay" => FaultRule::new(
                    site,
                    Action::Delay(Duration::from_millis(1)),
                ),
                "torn" => {
                    if site != SiteKind::IoWrite {
                        return Err(format!(
                            "clause {clause:?}: torn only applies to io_write"
                        ));
                    }
                    FaultRule::new(site, Action::Torn { at: 0 })
                }
                other => {
                    return Err(format!(
                        "unknown fault action {other:?} (panic|delay|torn)"
                    ))
                }
            };
            for pair in kv.split(',') {
                let pair = pair.trim();
                if pair.is_empty() {
                    continue;
                }
                let (k, v) = pair.split_once('=').ok_or_else(|| {
                    format!("clause {clause:?}: expected key=value, got {pair:?}")
                })?;
                let num = || -> Result<u64, String> {
                    v.parse::<u64>().map_err(|_| {
                        format!("clause {clause:?}: {k}={v:?} is not an integer")
                    })
                };
                match k {
                    "shard" => rule.shard = Some(num()? as usize),
                    "request" => rule.request = Some(num()?),
                    "nth" => rule.nth = Some(num()?),
                    "every" => {
                        let k = num()?;
                        if k == 0 {
                            return Err(format!(
                                "clause {clause:?}: every=0 never fires"
                            ));
                        }
                        rule.every = Some(k);
                    }
                    "prob" => {
                        let p = v.parse::<f64>().map_err(|_| {
                            format!("clause {clause:?}: prob={v:?} is not a number")
                        })?;
                        if !(0.0..=1.0).contains(&p) {
                            return Err(format!(
                                "clause {clause:?}: prob must be in [0, 1]"
                            ));
                        }
                        rule.prob = Some(p);
                    }
                    "times" => rule.times = Some(num()?),
                    "ms" => {
                        if !matches!(rule.action, Action::Delay(_)) {
                            return Err(format!(
                                "clause {clause:?}: ms= only applies to delay"
                            ));
                        }
                        rule.action =
                            Action::Delay(Duration::from_millis(num()?));
                    }
                    "at" => {
                        if !matches!(rule.action, Action::Torn { .. }) {
                            return Err(format!(
                                "clause {clause:?}: at= only applies to torn"
                            ));
                        }
                        rule.action = Action::Torn { at: num()? };
                    }
                    other => {
                        return Err(format!(
                            "clause {clause:?}: unknown key {other:?}"
                        ))
                    }
                }
            }
            rules.push(rule);
        }
        if rules.is_empty() {
            return Err("empty fault spec".into());
        }
        Ok(FaultPlan::new(rules, seed))
    }

    /// The plan from `SPC5_FAULTS` / `SPC5_FAULTS_SEED`, if set.
    /// Malformed specs panic: a chaos run with a typo'd schedule must
    /// not silently test nothing.
    pub fn from_env() -> Option<FaultPlan> {
        let spec = std::env::var("SPC5_FAULTS").ok()?;
        if spec.trim().is_empty() {
            return None;
        }
        let seed = std::env::var("SPC5_FAULTS_SEED")
            .ok()
            .and_then(|s| s.parse::<u64>().ok())
            .unwrap_or(DEFAULT_SEED);
        Some(
            FaultPlan::parse(&spec, seed)
                .unwrap_or_else(|e| panic!("SPC5_FAULTS: {e}")),
        )
    }

    /// Total fires across all rules so far.
    pub fn fired(&self) -> u64 {
        self.total_fires.load(Ordering::Relaxed)
    }

    /// The plan's seed (drives `prob` decisions).
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Checks `site` against every rule in order and returns the
    /// first firing rule's `(index, action)` without executing it.
    /// Hit counters advance exactly as for [`FaultPlan::fire`].
    pub fn decide(&self, site: Site) -> Option<(usize, Action)> {
        for (idx, rule) in self.rules.iter().enumerate() {
            if !rule.matches(&site) {
                continue;
            }
            if !rule.should_fire(self.seed, idx) {
                continue;
            }
            self.total_fires.fetch_add(1, Ordering::Relaxed);
            return Some((idx, rule.action));
        }
        None
    }

    /// Checks `site` against every rule in order; the first rule that
    /// fires acts (a `panic` action unwinds from here). A `torn`
    /// action outside its writer-cooperating site panics too — the
    /// parser rejects such plans, so reaching it means a
    /// hand-constructed rule at the wrong site.
    pub fn fire(&self, site: Site) {
        if let Some((idx, action)) = self.decide(site) {
            match action {
                Action::Panic | Action::Torn { .. } => panic!(
                    "spc5 injected fault: panic@{} ({site:?}, rule {idx})",
                    self.rules[idx].site.name()
                ),
                Action::Delay(d) => std::thread::sleep(d),
            }
        }
    }

    /// IO-site check: executes `panic`/`delay` inline and hands a
    /// firing `torn{at}` back to the writer as `Some(at)`.
    pub fn check_io(&self, site: Site) -> Option<u64> {
        match self.decide(site) {
            Some((idx, Action::Panic)) => panic!(
                "spc5 injected fault: panic@{} ({site:?}, rule {idx})",
                self.rules[idx].site.name()
            ),
            Some((_, Action::Delay(d))) => {
                std::thread::sleep(d);
                None
            }
            Some((_, Action::Torn { at })) => Some(at),
            None => None,
        }
    }
}

/// Checks a site against an optional plan — the form every injection
/// site uses. `None` costs one branch.
#[inline]
pub fn fire(plan: &Option<Arc<FaultPlan>>, site: Site) {
    if let Some(p) = plan {
        p.fire(site);
    }
}

// --- Process-global plan ------------------------------------------------

/// Fast-path flag: true only while a global plan is installed.
static GLOBAL_ACTIVE: AtomicBool = AtomicBool::new(false);
static GLOBAL_PLAN: RwLock<Option<Arc<FaultPlan>>> = RwLock::new(None);
/// Serializes [`install_global`] holders across tests.
static INSTALL_LOCK: Mutex<()> = Mutex::new(());
static ENV_INIT: OnceLock<()> = OnceLock::new();

fn ensure_env_plan() {
    ENV_INIT.get_or_init(|| {
        if let Some(plan) = FaultPlan::from_env() {
            *GLOBAL_PLAN.write().unwrap_or_else(|e| e.into_inner()) =
                Some(Arc::new(plan));
            GLOBAL_ACTIVE.store(true, Ordering::Release);
        }
    });
}

/// The process-global plan: `SPC5_FAULTS` parsed once, or whatever an
/// [`InstallGuard`] has installed. `None` in the common (fault-free)
/// case — the serving constructors call this as their fallback.
pub fn global() -> Option<Arc<FaultPlan>> {
    ensure_env_plan();
    if !GLOBAL_ACTIVE.load(Ordering::Acquire) {
        return None;
    }
    GLOBAL_PLAN.read().unwrap_or_else(|e| e.into_inner()).clone()
}

/// One-branch check-and-fire against the global plan — the form the
/// worker pool uses (it has no per-service plan handle).
#[inline]
pub fn fire_global(site: Site) {
    if !GLOBAL_ACTIVE.load(Ordering::Relaxed) {
        // Sites compiled into the pool hot loop cost exactly this
        // load before the first env read; `ensure_env_plan` runs from
        // `global()`, which every service constructor calls.
        ensure_env_plan();
        if !GLOBAL_ACTIVE.load(Ordering::Relaxed) {
            return;
        }
    }
    if let Some(p) =
        GLOBAL_PLAN.read().unwrap_or_else(|e| e.into_inner()).as_ref()
    {
        p.fire(site);
    }
}

/// One-branch IO-site check against the global plan — the form the
/// durable state layer uses. Executes `panic`/`delay` inline; a firing
/// `torn{at}` comes back as `Some(at)` for the writer to honor.
#[inline]
pub fn check_io_global(site: Site) -> Option<u64> {
    if !GLOBAL_ACTIVE.load(Ordering::Relaxed) {
        ensure_env_plan();
        if !GLOBAL_ACTIVE.load(Ordering::Relaxed) {
            return None;
        }
    }
    GLOBAL_PLAN
        .read()
        .unwrap_or_else(|e| e.into_inner())
        .as_ref()
        .and_then(|p| p.check_io(site))
}

/// RAII installation of a global plan for the duration of a test.
/// Holds a process-wide lock so concurrent `install_global` users
/// serialize; dropping restores the previous global plan (usually the
/// fault-free state, or the `SPC5_FAULTS` env plan under a chaos job).
pub struct InstallGuard {
    previous: Option<Arc<FaultPlan>>,
    _serial: std::sync::MutexGuard<'static, ()>,
}

/// Installs `plan` as the process-global plan until the guard drops.
pub fn install_global(plan: Arc<FaultPlan>) -> InstallGuard {
    let serial =
        INSTALL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    ensure_env_plan();
    let previous = {
        let mut slot =
            GLOBAL_PLAN.write().unwrap_or_else(|e| e.into_inner());
        std::mem::replace(&mut *slot, Some(plan))
    };
    GLOBAL_ACTIVE.store(true, Ordering::Release);
    InstallGuard { previous, _serial: serial }
}

impl Drop for InstallGuard {
    fn drop(&mut self) {
        let previous = self.previous.take();
        let active = previous.is_some();
        *GLOBAL_PLAN.write().unwrap_or_else(|e| e.into_inner()) = previous;
        GLOBAL_ACTIVE.store(active, Ordering::Release);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips_the_readme_examples() {
        let plan =
            FaultPlan::parse("panic@compute:shard=1,nth=3", 7).unwrap();
        assert_eq!(plan.rules.len(), 1);
        let r = &plan.rules[0];
        assert_eq!(r.site, SiteKind::Compute);
        assert_eq!(r.shard, Some(1));
        assert_eq!(r.nth, Some(3));
        assert_eq!(r.action, Action::Panic);

        let plan = FaultPlan::parse(
            "delay@recv:ms=5,every=2;panic@worker:shard=0,times=1",
            7,
        )
        .unwrap();
        assert_eq!(plan.rules.len(), 2);
        assert_eq!(
            plan.rules[0].action,
            Action::Delay(Duration::from_millis(5))
        );
        assert_eq!(plan.rules[0].every, Some(2));
        assert_eq!(plan.rules[1].site, SiteKind::Worker);
        assert_eq!(plan.rules[1].times, Some(1));
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "",
            "panic",
            "panic@elsewhere",
            "explode@compute",
            "panic@compute:nth",
            "panic@compute:prob=2.0",
            "panic@compute:every=0",
            "panic@compute:ms=3",
            "panic@compute:color=red",
            "torn@compute:at=4",
            "torn@io_read:at=4",
            "panic@io_write:at=4",
        ] {
            assert!(FaultPlan::parse(bad, 0).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn parse_accepts_io_sites_and_torn() {
        let plan = FaultPlan::parse(
            "torn@io_write:at=16,nth=0;delay@io_read:ms=2",
            0,
        )
        .unwrap();
        assert_eq!(plan.rules[0].site, SiteKind::IoWrite);
        assert_eq!(plan.rules[0].action, Action::Torn { at: 16 });
        assert_eq!(plan.rules[0].nth, Some(0));
        assert_eq!(plan.rules[1].site, SiteKind::IoRead);
    }

    #[test]
    fn check_io_hands_torn_to_the_writer() {
        let plan = FaultPlan::parse("torn@io_write:at=7,nth=1", 0).unwrap();
        // Hit 0: rule matches but nth=1 does not trigger.
        assert_eq!(plan.check_io(Site::IoWrite), None);
        // Hit 1: fires, and the action comes back instead of panicking.
        assert_eq!(plan.check_io(Site::IoWrite), Some(7));
        assert_eq!(plan.check_io(Site::IoWrite), None);
        // io_read never matches an io_write rule.
        assert_eq!(plan.check_io(Site::IoRead), None);
        assert_eq!(plan.fired(), 1);
    }

    #[test]
    fn nth_fires_exactly_once_at_the_right_hit() {
        let plan = FaultPlan::new(
            vec![FaultRule::new(SiteKind::Recv, Action::Delay(
                Duration::from_millis(0),
            ))
            .nth(2)],
            0,
        );
        for i in 0..6 {
            plan.fire(Site::Recv { shard: 0 });
            let want = if i >= 2 { 1 } else { 0 };
            assert_eq!(plan.fired(), want, "after hit {i}");
        }
    }

    #[test]
    fn every_fires_on_multiples() {
        let plan = FaultPlan::new(
            vec![FaultRule::new(SiteKind::Submit, Action::Delay(
                Duration::from_millis(0),
            ))
            .every(3)],
            0,
        );
        for _ in 0..9 {
            plan.fire(Site::Submit { shard: 0, request: 0 });
        }
        assert_eq!(plan.fired(), 3);
    }

    #[test]
    fn times_caps_total_fires() {
        let plan = FaultPlan::new(
            vec![FaultRule::new(SiteKind::Recv, Action::Delay(
                Duration::from_millis(0),
            ))
            .times(2)],
            0,
        );
        for _ in 0..10 {
            plan.fire(Site::Recv { shard: 3 });
        }
        assert_eq!(plan.fired(), 2);
    }

    #[test]
    fn filters_restrict_matching() {
        let plan = FaultPlan::new(
            vec![FaultRule::new(SiteKind::Compute, Action::Delay(
                Duration::from_millis(0),
            ))
            .shard(1)
            .request(42)],
            0,
        );
        plan.fire(Site::Compute { shard: 0, request: 42 });
        plan.fire(Site::Compute { shard: 1, request: 41 });
        plan.fire(Site::Submit { shard: 1, request: 42 });
        assert_eq!(plan.fired(), 0);
        plan.fire(Site::Compute { shard: 1, request: 42 });
        assert_eq!(plan.fired(), 1);
    }

    #[test]
    fn prob_is_deterministic_for_a_seed() {
        let run = |seed: u64| -> Vec<bool> {
            let plan = FaultPlan::new(
                vec![FaultRule::new(SiteKind::Recv, Action::Delay(
                    Duration::from_millis(0),
                ))
                .prob(0.5)],
                seed,
            );
            (0..64)
                .map(|_| {
                    let before = plan.fired();
                    plan.fire(Site::Recv { shard: 0 });
                    plan.fired() > before
                })
                .collect()
        };
        let a = run(7);
        assert_eq!(a, run(7), "same seed, same decisions");
        assert_ne!(a, run(8), "different seed diverges somewhere");
        let fires = a.iter().filter(|&&f| f).count();
        assert!(
            (16..=48).contains(&fires),
            "p=0.5 over 64 hits fired {fires} times"
        );
    }

    #[test]
    fn panic_action_unwinds_with_a_labelled_payload() {
        let plan = Arc::new(FaultPlan::new(
            vec![FaultRule::new(SiteKind::Compute, Action::Panic).nth(0)],
            0,
        ));
        let p = Arc::clone(&plan);
        let err = std::panic::catch_unwind(move || {
            p.fire(Site::Compute { shard: 0, request: 9 });
        })
        .unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("spc5 injected fault"), "payload: {msg}");
        assert_eq!(plan.fired(), 1);
    }

    #[test]
    fn install_guard_scopes_the_global_plan() {
        {
            let plan = Arc::new(FaultPlan::new(
                vec![FaultRule::new(SiteKind::Recv, Action::Delay(
                    Duration::from_millis(0),
                ))],
                0,
            ));
            let _g = install_global(Arc::clone(&plan));
            fire_global(Site::Recv { shard: 0 });
            assert_eq!(plan.fired(), 1);
        }
        // Guard dropped: the global site is inert again (unless the
        // environment carries a plan, in which case it is not ours).
        if std::env::var("SPC5_FAULTS").is_err() {
            assert!(global().is_none());
        }
    }
}
