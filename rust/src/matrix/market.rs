//! MatrixMarket (.mtx) reader / writer.
//!
//! Supports the `matrix coordinate (real|integer|pattern)
//! (general|symmetric|skew-symmetric)` subset — everything the
//! SuiteSparse collection uses for the paper's benchmark sets — plus
//! `array real general` for small dense inputs.
//!
//! The reader is a **bounded-memory streaming parser** hardened
//! against adversarial input (a tenant upload is untrusted):
//!
//! - one reusable line buffer, capped at [`MAX_LINE`] bytes — no
//!   input can force unbounded buffering;
//! - up-front allocation from header claims is capped at
//!   [`PREALLOC_CAP`] entries — a bogus `4000000000 4000000000`
//!   size line cannot OOM the process;
//! - every arithmetic step on header-supplied numbers is
//!   overflow-checked, indices are validated against both the
//!   declared dimensions and the `u32` storage range of
//!   [`Coo`], and non-finite values are rejected;
//! - the entry count is checked against the header *while
//!   streaming* (excess entries fail at their line, not at EOF);
//! - symmetric / skew-symmetric files must store the lower
//!   triangle only (skew excludes the diagonal), so the mirror
//!   expansion is bounded by construction.
//!
//! Every failure is a line-numbered [`MatrixError::Market`]; the
//! parser never panics, the mutation-corpus tests pin that down.

use super::{Coo, MatrixError, Result};
use crate::scalar::Scalar;
use std::io::{BufRead, BufReader, Read, Write};
use std::path::Path;

/// Longest accepted input line, in bytes.
pub const MAX_LINE: usize = 1 << 20;
/// Cap on entries/values reserved up front from header claims.
pub const PREALLOC_CAP: usize = 1 << 20;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Field {
    Real,
    Integer,
    Pattern,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Symmetry {
    General,
    Symmetric,
    SkewSymmetric,
}

fn err(line: usize, msg: impl Into<String>) -> MatrixError {
    MatrixError::Market { line, msg: msg.into() }
}

/// Streaming line reader over a [`BufRead`]: one reusable buffer,
/// hard length cap, physical line numbering from 1.
struct LineStream<R: Read> {
    inner: BufReader<R>,
    buf: Vec<u8>,
    lineno: usize,
}

impl<R: Read> LineStream<R> {
    fn new(reader: R) -> LineStream<R> {
        LineStream {
            inner: BufReader::new(reader),
            buf: Vec::new(),
            lineno: 0,
        }
    }

    /// Reads the next physical line into the reusable buffer (without
    /// the newline). `Ok(false)` at EOF. A line longer than
    /// [`MAX_LINE`] is a typed error, not unbounded buffering.
    fn fill_line(&mut self) -> Result<bool> {
        self.buf.clear();
        let started = loop {
            let chunk = self.inner.fill_buf().map_err(MatrixError::Io)?;
            if chunk.is_empty() {
                break !self.buf.is_empty();
            }
            match chunk.iter().position(|&b| b == b'\n') {
                Some(pos) => {
                    if self.buf.len() + pos > MAX_LINE {
                        self.lineno += 1;
                        return Err(err(
                            self.lineno,
                            format!("line longer than {MAX_LINE} bytes"),
                        ));
                    }
                    self.buf.extend_from_slice(&chunk[..pos]);
                    self.inner.consume(pos + 1);
                    break true;
                }
                None => {
                    if self.buf.len() + chunk.len() > MAX_LINE {
                        self.lineno += 1;
                        return Err(err(
                            self.lineno,
                            format!("line longer than {MAX_LINE} bytes"),
                        ));
                    }
                    self.buf.extend_from_slice(chunk);
                    let n = chunk.len();
                    self.inner.consume(n);
                }
            }
        };
        if started {
            self.lineno += 1;
        }
        Ok(started)
    }

    /// The current line as trimmed UTF-8 (typed error on bad bytes).
    fn line(&self) -> Result<&str> {
        std::str::from_utf8(&self.buf)
            .map(|s| s.trim())
            .map_err(|_| err(self.lineno, "line is not valid UTF-8"))
    }

    /// Advances to the next non-empty, non-comment line; `Ok(false)`
    /// at EOF. The line is then available through [`Self::line`].
    fn next_data(&mut self) -> Result<bool> {
        loop {
            if !self.fill_line()? {
                return Ok(false);
            }
            let t = self.line()?;
            if !t.is_empty() && !t.starts_with('%') {
                return Ok(true);
            }
        }
    }
}

/// Parses a dimension token: a positive-fitting integer no larger
/// than `u32::MAX` (the [`Coo`] triplet index range — anything larger
/// would silently truncate).
fn parse_dim(tok: &str, line: usize, what: &str) -> Result<usize> {
    let n: u64 = tok
        .parse()
        .map_err(|_| err(line, format!("bad {what} '{tok}'")))?;
    if n > u32::MAX as u64 {
        return Err(err(
            line,
            format!("{what} {n} exceeds the supported maximum {}", u32::MAX),
        ));
    }
    Ok(n as usize)
}

/// Parses a value token, rejecting non-finite results (NaN, explicit
/// infinities, and overflowing literals like `1e999`).
fn parse_value(tok: &str, line: usize) -> Result<f64> {
    let v: f64 = tok
        .parse()
        .map_err(|_| err(line, format!("bad value '{tok}'")))?;
    if !v.is_finite() {
        return Err(err(line, format!("non-finite value '{tok}'")));
    }
    Ok(v)
}

/// Reads a MatrixMarket stream into COO at any precision (values are
/// parsed as f64 and converted through [`Scalar::from_f64`]). See the
/// module docs for the hardening contract: bounded memory,
/// line-numbered typed errors, no panics on adversarial input.
pub fn read_coo<T: Scalar, R: Read>(reader: R) -> Result<Coo<T>> {
    let mut lines = LineStream::new(reader);

    // Header line.
    if !lines.fill_line()? {
        return Err(err(1, "empty file"));
    }
    let h: Vec<String> = lines
        .line()?
        .split_whitespace()
        .map(|t| t.to_ascii_lowercase())
        .collect();
    if h.len() < 4 || h[0] != "%%matrixmarket" || h[1] != "matrix" {
        return Err(err(1, "not a MatrixMarket matrix header"));
    }
    if h.len() > 5 {
        return Err(err(1, "too many header fields"));
    }
    let coordinate = match h[2].as_str() {
        "coordinate" => true,
        "array" => false,
        other => return Err(err(1, format!("unsupported format '{other}'"))),
    };
    let field = match h[3].as_str() {
        "real" => Field::Real,
        "integer" => Field::Integer,
        "pattern" => Field::Pattern,
        other => return Err(err(1, format!("unsupported field '{other}'"))),
    };
    let symmetry = match h.get(4).map(|s| s.as_str()).unwrap_or("general") {
        "general" => Symmetry::General,
        "symmetric" => Symmetry::Symmetric,
        "skew-symmetric" => Symmetry::SkewSymmetric,
        other => return Err(err(1, format!("unsupported symmetry '{other}'"))),
    };
    if !coordinate && field == Field::Pattern {
        return Err(err(1, "array+pattern is not a valid combination"));
    }
    if !coordinate && symmetry != Symmetry::General {
        return Err(err(1, "array format only supports general symmetry"));
    }

    // Skip comments, find the size line.
    if !lines.next_data()? {
        return Err(err(lines.lineno.max(1), "missing size line"));
    }
    let lineno = lines.lineno;
    let dims: Vec<String> =
        lines.line()?.split_whitespace().map(|t| t.to_string()).collect();

    if coordinate {
        if dims.len() != 3 {
            return Err(err(lineno, "coordinate size line needs 3 numbers"));
        }
        let rows = parse_dim(&dims[0], lineno, "row count")?;
        let cols = parse_dim(&dims[1], lineno, "column count")?;
        let nnz: u64 = dims[2]
            .parse()
            .map_err(|_| err(lineno, format!("bad entry count '{}'", dims[2])))?;
        // Sanity-bound the claim before trusting it anywhere: a
        // general file cannot hold more distinct entries than the
        // dense size (symmetric files store at most the lower
        // triangle, which is smaller still).
        if nnz > rows as u64 * cols as u64 {
            return Err(err(
                lineno,
                format!("entry count {nnz} exceeds rows*cols"),
            ));
        }
        let nnz = nnz as usize;
        let mut coo = Coo::new(rows, cols);
        // Mirror expansion at most doubles; cap what the header alone
        // can make us allocate.
        coo.entries.reserve(nnz.min(PREALLOC_CAP));
        let mut seen = 0usize;
        while lines.next_data()? {
            let lno = lines.lineno;
            if seen == nnz {
                return Err(err(
                    lno,
                    format!("more entries than the declared {nnz}"),
                ));
            }
            let t = lines.line()?;
            let mut toks = t.split_whitespace();
            let need = if field == Field::Pattern { 2 } else { 3 };
            let mut take = || {
                toks.next().ok_or_else(|| {
                    err(lno, format!("entry needs {need} fields"))
                })
            };
            let r = parse_dim(take()?, lno, "row index")?;
            let c = parse_dim(take()?, lno, "col index")?;
            let v = match field {
                Field::Pattern => 1.0,
                _ => parse_value(take()?, lno)?,
            };
            if toks.next().is_some() {
                return Err(err(
                    lno,
                    format!("entry has more than {need} fields"),
                ));
            }
            if r < 1 || r > rows || c < 1 || c > cols {
                return Err(err(lno, format!("index ({r},{c}) out of range")));
            }
            match symmetry {
                Symmetry::Symmetric if r < c => {
                    return Err(err(
                        lno,
                        format!(
                            "symmetric file must store the lower triangle: \
                             entry ({r},{c})"
                        ),
                    ))
                }
                Symmetry::SkewSymmetric if r <= c => {
                    return Err(err(
                        lno,
                        format!(
                            "skew-symmetric file must store the strict lower \
                             triangle: entry ({r},{c})"
                        ),
                    ))
                }
                _ => {}
            }
            let v = T::from_f64(v);
            coo.push(r - 1, c - 1, v);
            match symmetry {
                Symmetry::General => {}
                Symmetry::Symmetric if r != c => coo.push(c - 1, r - 1, v),
                Symmetry::SkewSymmetric => coo.push(c - 1, r - 1, -v),
                _ => {}
            }
            seen += 1;
        }
        if seen != nnz {
            return Err(err(
                lines.lineno.max(lineno),
                format!("entry count mismatch: header says {nnz}, found {seen}"),
            ));
        }
        Ok(coo)
    } else {
        if dims.len() != 2 {
            return Err(err(lineno, "array size line needs 2 numbers"));
        }
        let rows = parse_dim(&dims[0], lineno, "row count")?;
        let cols = parse_dim(&dims[1], lineno, "column count")?;
        let total = rows.checked_mul(cols).ok_or_else(|| {
            err(lineno, "rows*cols overflows the addressable size")
        })?;
        let mut vals: Vec<f64> = Vec::with_capacity(total.min(PREALLOC_CAP));
        while lines.next_data()? {
            let lno = lines.lineno;
            for tok in lines.line()?.split_whitespace() {
                if vals.len() == total {
                    return Err(err(
                        lno,
                        format!("more values than the declared {total}"),
                    ));
                }
                vals.push(parse_value(tok, lno)?);
            }
        }
        if vals.len() != total {
            return Err(err(
                lines.lineno.max(lineno),
                format!("expected {total} values, found {}", vals.len()),
            ));
        }
        let mut coo = Coo::new(rows, cols);
        // Array format is column-major.
        for c in 0..cols {
            for r in 0..rows {
                let v = vals[c * rows + r];
                if v != 0.0 {
                    coo.push(r, c, T::from_f64(v));
                }
            }
        }
        Ok(coo)
    }
}

/// Reads a `.mtx` file into COO.
pub fn read_file<T: Scalar>(path: impl AsRef<Path>) -> Result<Coo<T>> {
    read_coo(std::fs::File::open(path)?)
}

/// Writes a COO matrix as `coordinate real general`.
pub fn write_coo<T: Scalar, W: Write>(mut w: W, coo: &Coo<T>) -> Result<()> {
    writeln!(w, "%%MatrixMarket matrix coordinate real general")?;
    writeln!(w, "% written by spc5-rs")?;
    writeln!(w, "{} {} {}", coo.rows, coo.cols, coo.entries.len())?;
    for &(r, c, v) in &coo.entries {
        writeln!(w, "{} {} {:.17e}", r + 1, c + 1, v)?;
    }
    Ok(())
}

/// Writes a `.mtx` file.
pub fn write_file<T: Scalar>(path: impl AsRef<Path>, coo: &Coo<T>) -> Result<()> {
    write_coo(std::fs::File::create(path)?, coo)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SIMPLE: &str = "%%MatrixMarket matrix coordinate real general\n\
         % comment\n\
         3 4 3\n\
         1 1 2.5\n\
         2 3 -1\n\
         3 4 7e-2\n";

    #[test]
    fn reads_general_real() {
        let coo = read_coo::<f64, _>(SIMPLE.as_bytes()).unwrap();
        assert_eq!((coo.rows, coo.cols), (3, 4));
        assert_eq!(coo.entries, vec![(0, 0, 2.5), (1, 2, -1.0), (2, 3, 0.07)]);
    }

    #[test]
    fn reads_f32() {
        let coo = read_coo::<f32, _>(SIMPLE.as_bytes()).unwrap();
        assert_eq!((coo.rows, coo.cols), (3, 4));
        assert_eq!(coo.entries[0], (0, 0, 2.5f32));
    }

    #[test]
    fn reads_symmetric() {
        let src = "%%MatrixMarket matrix coordinate real symmetric\n\
                   3 3 2\n1 1 4\n3 1 5\n";
        let coo = read_coo::<f64, _>(src.as_bytes()).unwrap();
        // diagonal kept once, off-diagonal mirrored
        assert_eq!(coo.entries.len(), 3);
        let csr = coo.to_csr().unwrap();
        assert_eq!(csr.to_dense().get(0, 2), 5.0);
        assert_eq!(csr.to_dense().get(2, 0), 5.0);
    }

    #[test]
    fn reads_skew_symmetric() {
        let src = "%%MatrixMarket matrix coordinate real skew-symmetric\n\
                   2 2 1\n2 1 3\n";
        let csr = read_coo::<f64, _>(src.as_bytes()).unwrap().to_csr().unwrap();
        assert_eq!(csr.to_dense().get(1, 0), 3.0);
        assert_eq!(csr.to_dense().get(0, 1), -3.0);
    }

    #[test]
    fn reads_pattern() {
        let src = "%%MatrixMarket matrix coordinate pattern general\n\
                   2 2 2\n1 2\n2 1\n";
        let coo = read_coo::<f64, _>(src.as_bytes()).unwrap();
        assert!(coo.entries.iter().all(|&(_, _, v)| v == 1.0));
    }

    #[test]
    fn reads_array() {
        let src = "%%MatrixMarket matrix array real general\n\
                   2 2\n1\n0\n0\n4\n";
        let csr = read_coo::<f64, _>(src.as_bytes()).unwrap().to_csr().unwrap();
        assert_eq!(csr.nnz(), 2);
        assert_eq!(csr.to_dense().get(1, 1), 4.0);
    }

    #[test]
    fn roundtrip() {
        let coo = read_coo::<f64, _>(SIMPLE.as_bytes()).unwrap();
        let mut buf = Vec::new();
        write_coo(&mut buf, &coo).unwrap();
        let back = read_coo::<f64, _>(buf.as_slice()).unwrap();
        assert_eq!(coo.entries, back.entries);
    }

    #[test]
    fn rejects_bad_header() {
        assert!(read_coo::<f64, _>("garbage\n1 1 0\n".as_bytes()).is_err());
        assert!(read_coo::<f64, _>(
            "%%MatrixMarket matrix teapot real general\n1 1 0\n".as_bytes()
        )
        .is_err());
    }

    #[test]
    fn rejects_count_mismatch() {
        let src = "%%MatrixMarket matrix coordinate real general\n2 2 3\n1 1 1\n";
        assert!(read_coo::<f64, _>(src.as_bytes()).is_err());
    }

    #[test]
    fn rejects_out_of_range_index() {
        let src = "%%MatrixMarket matrix coordinate real general\n2 2 1\n3 1 1\n";
        assert!(read_coo::<f64, _>(src.as_bytes()).is_err());
    }

    #[test]
    fn rejects_truncated_entry() {
        let src = "%%MatrixMarket matrix coordinate real general\n2 2 1\n1 1\n";
        assert!(read_coo::<f64, _>(src.as_bytes()).is_err());
    }

    #[test]
    fn rejects_empty_file() {
        assert!(read_coo::<f64, _>("".as_bytes()).is_err());
    }

    #[test]
    fn one_indexed_conversion() {
        let coo = read_coo::<f64, _>(SIMPLE.as_bytes()).unwrap();
        assert_eq!(coo.entries[0].0, 0); // 1-indexed in file → 0-indexed
    }
}
