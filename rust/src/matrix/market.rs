//! MatrixMarket (.mtx) reader / writer.
//!
//! Supports the `matrix coordinate (real|integer|pattern)
//! (general|symmetric|skew-symmetric)` subset — everything the
//! SuiteSparse collection uses for the paper's benchmark sets — plus
//! `array real general` for small dense inputs. Parsing is
//! failure-injection tested (truncated files, bad counts, out-of-range
//! indices).

use super::{Coo, MatrixError, Result};
use crate::scalar::Scalar;
use std::io::{BufRead, BufReader, Read, Write};
use std::path::Path;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Field {
    Real,
    Integer,
    Pattern,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Symmetry {
    General,
    Symmetric,
    SkewSymmetric,
}

fn err(line: usize, msg: impl Into<String>) -> MatrixError {
    MatrixError::Market { line, msg: msg.into() }
}

/// Reads a MatrixMarket stream into COO at any precision (values are
/// parsed as f64 and converted through [`Scalar::from_f64`]).
pub fn read_coo<T: Scalar, R: Read>(reader: R) -> Result<Coo<T>> {
    let mut lines = BufReader::new(reader).lines().enumerate();

    // Header line.
    let (_, header) = lines
        .next()
        .ok_or_else(|| err(1, "empty file"))
        .and_then(|(i, l)| l.map(|l| (i, l)).map_err(MatrixError::Io))?;
    let h: Vec<String> =
        header.split_whitespace().map(|t| t.to_ascii_lowercase()).collect();
    if h.len() < 4 || h[0] != "%%matrixmarket" || h[1] != "matrix" {
        return Err(err(1, "not a MatrixMarket matrix header"));
    }
    let coordinate = match h[2].as_str() {
        "coordinate" => true,
        "array" => false,
        other => return Err(err(1, format!("unsupported format '{other}'"))),
    };
    let field = match h[3].as_str() {
        "real" => Field::Real,
        "integer" => Field::Integer,
        "pattern" => Field::Pattern,
        other => return Err(err(1, format!("unsupported field '{other}'"))),
    };
    let symmetry = match h.get(4).map(|s| s.as_str()).unwrap_or("general") {
        "general" => Symmetry::General,
        "symmetric" => Symmetry::Symmetric,
        "skew-symmetric" => Symmetry::SkewSymmetric,
        other => return Err(err(1, format!("unsupported symmetry '{other}'"))),
    };
    if !coordinate && field == Field::Pattern {
        return Err(err(1, "array+pattern is not a valid combination"));
    }

    // Skip comments, find the size line.
    let mut size_line = None;
    let mut lineno = 1;
    for (i, l) in &mut lines {
        lineno = i + 1;
        let l = l.map_err(MatrixError::Io)?;
        let t = l.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        size_line = Some(t.to_string());
        break;
    }
    let size_line = size_line.ok_or_else(|| err(lineno, "missing size line"))?;
    let dims: Vec<usize> = size_line
        .split_whitespace()
        .map(|t| t.parse::<usize>().map_err(|_| err(lineno, "bad size entry")))
        .collect::<Result<_>>()?;

    if coordinate {
        if dims.len() != 3 {
            return Err(err(lineno, "coordinate size line needs 3 numbers"));
        }
        let (rows, cols, nnz) = (dims[0], dims[1], dims[2]);
        let mut coo = Coo::new(rows, cols);
        let mut seen = 0usize;
        for (i, l) in &mut lines {
            let lno = i + 1;
            let l = l.map_err(MatrixError::Io)?;
            let t = l.trim();
            if t.is_empty() || t.starts_with('%') {
                continue;
            }
            let toks: Vec<&str> = t.split_whitespace().collect();
            let need = if field == Field::Pattern { 2 } else { 3 };
            if toks.len() < need {
                return Err(err(lno, "too few fields in entry"));
            }
            let r: usize =
                toks[0].parse().map_err(|_| err(lno, "bad row index"))?;
            let c: usize =
                toks[1].parse().map_err(|_| err(lno, "bad col index"))?;
            if r < 1 || r > rows || c < 1 || c > cols {
                return Err(err(lno, format!("index ({r},{c}) out of range")));
            }
            let v = match field {
                Field::Pattern => 1.0,
                _ => toks[2]
                    .parse::<f64>()
                    .map_err(|_| err(lno, "bad value"))?,
            };
            let v = T::from_f64(v);
            coo.push(r - 1, c - 1, v);
            match symmetry {
                Symmetry::General => {}
                Symmetry::Symmetric if r != c => coo.push(c - 1, r - 1, v),
                Symmetry::SkewSymmetric if r != c => coo.push(c - 1, r - 1, -v),
                _ => {}
            }
            seen += 1;
        }
        if seen != nnz {
            return Err(err(
                lineno,
                format!("entry count mismatch: header says {nnz}, found {seen}"),
            ));
        }
        Ok(coo)
    } else {
        if dims.len() != 2 {
            return Err(err(lineno, "array size line needs 2 numbers"));
        }
        let (rows, cols) = (dims[0], dims[1]);
        let mut vals = Vec::with_capacity(rows * cols);
        for (i, l) in &mut lines {
            let lno = i + 1;
            let l = l.map_err(MatrixError::Io)?;
            let t = l.trim();
            if t.is_empty() || t.starts_with('%') {
                continue;
            }
            for tok in t.split_whitespace() {
                vals.push(
                    tok.parse::<f64>().map_err(|_| err(lno, "bad value"))?,
                );
            }
        }
        if vals.len() != rows * cols {
            return Err(err(
                lineno,
                format!("expected {} values, found {}", rows * cols, vals.len()),
            ));
        }
        let mut coo = Coo::new(rows, cols);
        // Array format is column-major.
        for c in 0..cols {
            for r in 0..rows {
                let v = vals[c * rows + r];
                if v != 0.0 {
                    coo.push(r, c, T::from_f64(v));
                }
            }
        }
        Ok(coo)
    }
}

/// Reads a `.mtx` file into COO.
pub fn read_file<T: Scalar>(path: impl AsRef<Path>) -> Result<Coo<T>> {
    read_coo(std::fs::File::open(path)?)
}

/// Writes a COO matrix as `coordinate real general`.
pub fn write_coo<T: Scalar, W: Write>(mut w: W, coo: &Coo<T>) -> Result<()> {
    writeln!(w, "%%MatrixMarket matrix coordinate real general")?;
    writeln!(w, "% written by spc5-rs")?;
    writeln!(w, "{} {} {}", coo.rows, coo.cols, coo.entries.len())?;
    for &(r, c, v) in &coo.entries {
        writeln!(w, "{} {} {:.17e}", r + 1, c + 1, v)?;
    }
    Ok(())
}

/// Writes a `.mtx` file.
pub fn write_file<T: Scalar>(path: impl AsRef<Path>, coo: &Coo<T>) -> Result<()> {
    write_coo(std::fs::File::create(path)?, coo)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SIMPLE: &str = "%%MatrixMarket matrix coordinate real general\n\
         % comment\n\
         3 4 3\n\
         1 1 2.5\n\
         2 3 -1\n\
         3 4 7e-2\n";

    #[test]
    fn reads_general_real() {
        let coo = read_coo::<f64, _>(SIMPLE.as_bytes()).unwrap();
        assert_eq!((coo.rows, coo.cols), (3, 4));
        assert_eq!(coo.entries, vec![(0, 0, 2.5), (1, 2, -1.0), (2, 3, 0.07)]);
    }

    #[test]
    fn reads_f32() {
        let coo = read_coo::<f32, _>(SIMPLE.as_bytes()).unwrap();
        assert_eq!((coo.rows, coo.cols), (3, 4));
        assert_eq!(coo.entries[0], (0, 0, 2.5f32));
    }

    #[test]
    fn reads_symmetric() {
        let src = "%%MatrixMarket matrix coordinate real symmetric\n\
                   3 3 2\n1 1 4\n3 1 5\n";
        let coo = read_coo::<f64, _>(src.as_bytes()).unwrap();
        // diagonal kept once, off-diagonal mirrored
        assert_eq!(coo.entries.len(), 3);
        let csr = coo.to_csr().unwrap();
        assert_eq!(csr.to_dense().get(0, 2), 5.0);
        assert_eq!(csr.to_dense().get(2, 0), 5.0);
    }

    #[test]
    fn reads_skew_symmetric() {
        let src = "%%MatrixMarket matrix coordinate real skew-symmetric\n\
                   2 2 1\n2 1 3\n";
        let csr = read_coo::<f64, _>(src.as_bytes()).unwrap().to_csr().unwrap();
        assert_eq!(csr.to_dense().get(1, 0), 3.0);
        assert_eq!(csr.to_dense().get(0, 1), -3.0);
    }

    #[test]
    fn reads_pattern() {
        let src = "%%MatrixMarket matrix coordinate pattern general\n\
                   2 2 2\n1 2\n2 1\n";
        let coo = read_coo::<f64, _>(src.as_bytes()).unwrap();
        assert!(coo.entries.iter().all(|&(_, _, v)| v == 1.0));
    }

    #[test]
    fn reads_array() {
        let src = "%%MatrixMarket matrix array real general\n\
                   2 2\n1\n0\n0\n4\n";
        let csr = read_coo::<f64, _>(src.as_bytes()).unwrap().to_csr().unwrap();
        assert_eq!(csr.nnz(), 2);
        assert_eq!(csr.to_dense().get(1, 1), 4.0);
    }

    #[test]
    fn roundtrip() {
        let coo = read_coo::<f64, _>(SIMPLE.as_bytes()).unwrap();
        let mut buf = Vec::new();
        write_coo(&mut buf, &coo).unwrap();
        let back = read_coo::<f64, _>(buf.as_slice()).unwrap();
        assert_eq!(coo.entries, back.entries);
    }

    #[test]
    fn rejects_bad_header() {
        assert!(read_coo::<f64, _>("garbage\n1 1 0\n".as_bytes()).is_err());
        assert!(read_coo::<f64, _>(
            "%%MatrixMarket matrix teapot real general\n1 1 0\n".as_bytes()
        )
        .is_err());
    }

    #[test]
    fn rejects_count_mismatch() {
        let src = "%%MatrixMarket matrix coordinate real general\n2 2 3\n1 1 1\n";
        assert!(read_coo::<f64, _>(src.as_bytes()).is_err());
    }

    #[test]
    fn rejects_out_of_range_index() {
        let src = "%%MatrixMarket matrix coordinate real general\n2 2 1\n3 1 1\n";
        assert!(read_coo::<f64, _>(src.as_bytes()).is_err());
    }

    #[test]
    fn rejects_truncated_entry() {
        let src = "%%MatrixMarket matrix coordinate real general\n2 2 1\n1 1\n";
        assert!(read_coo::<f64, _>(src.as_bytes()).is_err());
    }

    #[test]
    fn rejects_empty_file() {
        assert!(read_coo::<f64, _>("".as_bytes()).is_err());
    }

    #[test]
    fn one_indexed_conversion() {
        let coo = read_coo::<f64, _>(SIMPLE.as_bytes()).unwrap();
        assert_eq!(coo.entries[0].0, 0); // 1-indexed in file → 0-indexed
    }
}
