//! Sparse-matrix substrate: COO and CSR containers, a dense oracle,
//! MatrixMarket I/O and the synthetic benchmark-suite generators that
//! stand in for the paper's SuiteSparse matrix sets.

pub mod coo;
pub mod csr;
pub mod dense;
pub mod market;
pub mod reorder;
pub mod suite;

pub use coo::Coo;
pub use csr::Csr;
pub use dense::Dense;

/// Errors produced by the matrix substrate.
#[derive(Debug, thiserror::Error)]
pub enum MatrixError {
    #[error("invalid matrix data: {0}")]
    Invalid(String),
    #[error("matrix market parse error at line {line}: {msg}")]
    Market { line: usize, msg: String },
    #[error(transparent)]
    Io(#[from] std::io::Error),
}

pub type Result<T> = std::result::Result<T, MatrixError>;
