//! Sparse-matrix substrate: COO and CSR containers (generic over
//! [`crate::scalar::Scalar`], `f64` by default), a dense oracle,
//! MatrixMarket I/O and the synthetic benchmark-suite generators that
//! stand in for the paper's SuiteSparse matrix sets.

pub mod coo;
pub mod csr;
pub mod dense;
pub mod market;
pub mod reorder;
pub mod suite;

pub use coo::Coo;
pub use csr::{Csr, TriangularSplit};
pub use dense::Dense;
pub use reorder::ReorderKind;

/// Errors produced by the matrix substrate.
#[derive(Debug)]
pub enum MatrixError {
    /// Structurally invalid matrix data.
    Invalid(String),
    /// MatrixMarket parse failure.
    Market { line: usize, msg: String },
    /// Underlying I/O failure.
    Io(std::io::Error),
}

impl std::fmt::Display for MatrixError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MatrixError::Invalid(msg) => {
                write!(f, "invalid matrix data: {msg}")
            }
            MatrixError::Market { line, msg } => {
                write!(f, "matrix market parse error at line {line}: {msg}")
            }
            MatrixError::Io(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for MatrixError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            MatrixError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for MatrixError {
    fn from(e: std::io::Error) -> Self {
        MatrixError::Io(e)
    }
}

pub type Result<T> = std::result::Result<T, MatrixError>;
