//! Dense matrix oracle — used only by tests and tiny examples to define
//! ground-truth SpMV semantics.

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Dense {
    pub rows: usize,
    pub cols: usize,
    data: Vec<f64>,
}

impl Dense {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Dense { rows, cols, data: vec![0.0; rows * cols] }
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f64 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f64) {
        self.data[r * self.cols + c] = v;
    }

    /// `y = A x` (fresh output).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0; self.rows];
        for r in 0..self.rows {
            let mut sum = 0.0;
            for c in 0..self.cols {
                sum += self.get(r, c) * x[c];
            }
            y[r] = sum;
        }
        y
    }

    /// Number of nonzero entries.
    pub fn nnz(&self) -> usize {
        self.data.iter().filter(|&&v| v != 0.0).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matvec_identity() {
        let mut m = Dense::zeros(3, 3);
        for i in 0..3 {
            m.set(i, i, 1.0);
        }
        let x = vec![3.0, -1.0, 2.5];
        assert_eq!(m.matvec(&x), x);
    }

    #[test]
    fn matvec_rectangular() {
        let mut m = Dense::zeros(2, 3);
        m.set(0, 0, 1.0);
        m.set(0, 2, 2.0);
        m.set(1, 1, -1.0);
        let y = m.matvec(&[1.0, 2.0, 3.0]);
        assert_eq!(y, vec![7.0, -2.0]);
    }

    #[test]
    fn nnz_counts() {
        let mut m = Dense::zeros(2, 2);
        assert_eq!(m.nnz(), 0);
        m.set(0, 1, 4.0);
        m.set(1, 0, -4.0);
        assert_eq!(m.nnz(), 2);
    }
}
