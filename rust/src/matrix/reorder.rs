//! Matrix reordering (paper §"Matrix permutation/reordering").
//!
//! The paper surveys two families and leaves them "aside from the
//! current study" while noting that "any improvement to the shape of
//! the matrix will certainly improve the efficiency of our kernels by
//! reducing the number of blocks". This module implements both so the
//! claim can be measured (bench `kernel_micro` ablation C):
//!
//! - [`cuthill_mckee`] — the classic bandwidth-reducing BFS ordering
//!   (Cuthill & McKee 1969), in its reverse variant (RCM);
//! - [`column_pack`] — a lightweight stand-in for the TSP column
//!   ordering of Pinar & Heath (1999): a greedy nearest-neighbour walk
//!   over columns where the edge weight is the number of rows in which
//!   two columns co-occur — putting frequently co-occurring columns
//!   next to each other grows contiguous runs, which is exactly what
//!   fills `β(r,c)` blocks.

use super::{Coo, Csr};
use crate::scalar::Scalar;

/// A reordering strategy the engine can apply at build time
/// (`SpmvEngine::builder(..).reorder(..)`; CLI `--reorder`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReorderKind {
    /// Reverse Cuthill–McKee: symmetric row+column permutation
    /// (square matrices only).
    Rcm,
    /// Greedy column packing (column permutation only).
    ColPack,
}

impl ReorderKind {
    /// Parses `rcm` / `colpack` (also `column-pack`, `column_pack`).
    pub fn parse(s: &str) -> Option<ReorderKind> {
        match s.trim().to_ascii_lowercase().as_str() {
            "rcm" => Some(ReorderKind::Rcm),
            "colpack" | "column-pack" | "column_pack" => {
                Some(ReorderKind::ColPack)
            }
            _ => None,
        }
    }
}

impl std::fmt::Display for ReorderKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ReorderKind::Rcm => write!(f, "rcm"),
            ReorderKind::ColPack => write!(f, "colpack"),
        }
    }
}

/// A permutation: `perm[new_index] = old_index`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Permutation {
    pub perm: Vec<u32>,
}

impl Permutation {
    /// Identity permutation of size `n`.
    pub fn identity(n: usize) -> Self {
        Permutation { perm: (0..n as u32).collect() }
    }

    /// Validates this is a bijection on `0..n`.
    pub fn validate(&self) -> bool {
        let n = self.perm.len();
        let mut seen = vec![false; n];
        for &p in &self.perm {
            if p as usize >= n || seen[p as usize] {
                return false;
            }
            seen[p as usize] = true;
        }
        true
    }

    /// Inverse permutation: `inv[old_index] = new_index`.
    pub fn inverse(&self) -> Permutation {
        let mut inv = vec![0u32; self.perm.len()];
        for (new, &old) in self.perm.iter().enumerate() {
            inv[old as usize] = new as u32;
        }
        Permutation { perm: inv }
    }
}

/// Applies row and column permutations to a matrix:
/// `B[i, j] = A[row_perm[i], col_perm[j]]`.
pub fn permute<T: Scalar>(
    csr: &Csr<T>,
    rows: &Permutation,
    cols: &Permutation,
) -> Csr<T> {
    assert_eq!(rows.perm.len(), csr.rows);
    assert_eq!(cols.perm.len(), csr.cols);
    let col_inv = cols.inverse();
    let mut coo = Coo::new(csr.rows, csr.cols);
    for (new_r, &old_r) in rows.perm.iter().enumerate() {
        for k in csr.row_range(old_r as usize) {
            let new_c = col_inv.perm[csr.colidx[k] as usize] as usize;
            coo.push(new_r, new_c, csr.values[k]);
        }
    }
    coo.to_csr().expect("permutation preserves validity")
}

/// Permutes a vector into the reordered space: `out[i] = x[perm[i]]`.
pub fn permute_vec<T: Scalar>(x: &[T], p: &Permutation) -> Vec<T> {
    p.perm.iter().map(|&old| x[old as usize]).collect()
}

/// Reverse Cuthill–McKee ordering on the symmetrized pattern of a
/// square matrix. Returns a row/column permutation that reduces
/// bandwidth (and, for FEM-class matrices, concentrates the pattern
/// near the diagonal, improving block fill).
pub fn cuthill_mckee<T: Scalar>(csr: &Csr<T>) -> Permutation {
    assert_eq!(csr.rows, csr.cols, "RCM needs a square matrix");
    let n = csr.rows;
    // Symmetrized adjacency (pattern of A + Aᵀ, diagonal dropped).
    let t = csr.transpose();
    let mut adj: Vec<Vec<u32>> = vec![Vec::new(); n];
    for r in 0..n {
        for k in csr.row_range(r) {
            let c = csr.colidx[k] as usize;
            if c != r {
                adj[r].push(c as u32);
            }
        }
        for k in t.row_range(r) {
            let c = t.colidx[k] as usize;
            if c != r {
                adj[r].push(c as u32);
            }
        }
    }
    for a in &mut adj {
        a.sort_unstable();
        a.dedup();
    }
    let degree: Vec<usize> = adj.iter().map(|a| a.len()).collect();

    let mut order: Vec<u32> = Vec::with_capacity(n);
    let mut visited = vec![false; n];
    // Process components from lowest-degree unvisited seed (the
    // standard pseudo-peripheral heuristic, simplified).
    let mut seeds: Vec<u32> = (0..n as u32).collect();
    seeds.sort_by_key(|&v| degree[v as usize]);
    for &seed in &seeds {
        if visited[seed as usize] {
            continue;
        }
        visited[seed as usize] = true;
        let mut queue = std::collections::VecDeque::from([seed]);
        while let Some(v) = queue.pop_front() {
            order.push(v);
            // Neighbours in increasing degree order (CM rule).
            let mut nbrs: Vec<u32> = adj[v as usize]
                .iter()
                .copied()
                .filter(|&u| !visited[u as usize])
                .collect();
            nbrs.sort_by_key(|&u| degree[u as usize]);
            for u in nbrs {
                visited[u as usize] = true;
                queue.push_back(u);
            }
        }
    }
    order.reverse(); // the "reverse" in RCM
    Permutation { perm: order }
}

/// Greedy column packing — the TSP-ordering stand-in (Pinar & Heath):
/// columns are visited in a nearest-neighbour walk where closeness is
/// co-occurrence weight, sampled over a bounded number of rows per
/// column to stay `O(nnz·w)`.
pub fn column_pack<T: Scalar>(csr: &Csr<T>) -> Permutation {
    let n = csr.cols;
    let t = csr.transpose(); // rows of `t` = columns of `csr`
    let mut visited = vec![false; n];
    let mut order: Vec<u32> = Vec::with_capacity(n);

    // Count co-occurrence of column pairs through a sampled row walk.
    // For each column c we look at the rows containing it and collect
    // the other columns of those rows (capped), then walk greedily.
    let mut cur = (0..n).max_by_key(|&c| t.row_range(c).len()).unwrap_or(0);
    const ROW_CAP: usize = 48;
    loop {
        visited[cur] = true;
        order.push(cur as u32);
        if order.len() == n {
            break;
        }
        // Score candidate next columns by co-occurrence with `cur`.
        let mut scores: std::collections::HashMap<u32, u32> =
            std::collections::HashMap::new();
        for k in t.row_range(cur).take(ROW_CAP) {
            let row = t.colidx[k] as usize; // a row containing column cur
            for kk in csr.row_range(row).take(ROW_CAP) {
                let c2 = csr.colidx[kk];
                if !visited[c2 as usize] {
                    *scores.entry(c2).or_insert(0) += 1;
                }
            }
        }
        cur = match scores.iter().max_by_key(|(_, &s)| s) {
            Some((&c2, _)) => c2 as usize,
            None => match visited.iter().position(|&v| !v) {
                Some(c2) => c2,
                None => break,
            },
        };
    }
    Permutation { perm: order }
}

/// Bandwidth of a matrix (max |r - c| over nonzeros) — the quantity RCM
/// minimizes; used by tests and the ablation bench.
pub fn bandwidth<T: Scalar>(csr: &Csr<T>) -> usize {
    let mut bw = 0usize;
    for r in 0..csr.rows {
        for k in csr.row_range(r) {
            bw = bw.max((csr.colidx[k] as i64 - r as i64).unsigned_abs() as usize);
        }
    }
    bw
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::stats::block_stats;
    use crate::formats::BlockSize;
    use crate::matrix::suite;
    use crate::util::Rng;

    #[test]
    fn reorder_kind_parses() {
        assert_eq!(ReorderKind::parse("rcm"), Some(ReorderKind::Rcm));
        assert_eq!(ReorderKind::parse("RCM"), Some(ReorderKind::Rcm));
        assert_eq!(ReorderKind::parse("colpack"), Some(ReorderKind::ColPack));
        assert_eq!(
            ReorderKind::parse("column-pack"),
            Some(ReorderKind::ColPack)
        );
        assert_eq!(ReorderKind::parse("tsp"), None);
        assert_eq!(ReorderKind::Rcm.to_string(), "rcm");
        assert_eq!(ReorderKind::ColPack.to_string(), "colpack");
    }

    #[test]
    fn identity_roundtrip() {
        let csr = suite::poisson2d(8);
        let id = Permutation::identity(csr.rows);
        assert!(id.validate());
        let p = permute(&csr, &id, &id);
        assert_eq!(csr, p);
    }

    #[test]
    fn inverse_composes_to_identity() {
        let mut rng = Rng::new(4);
        let mut perm: Vec<u32> = (0..100).collect();
        for i in (1..100usize).rev() {
            let j = rng.next_below(i + 1);
            perm.swap(i, j);
        }
        let p = Permutation { perm };
        assert!(p.validate());
        let inv = p.inverse();
        for old in 0..100u32 {
            assert_eq!(p.perm[inv.perm[old as usize] as usize], old);
        }
    }

    #[test]
    fn permute_preserves_spmv_semantics() {
        // y' = B x' with B = P A Qᵀ must satisfy y'[i] = y[rp[i]] when
        // x'[j] = x[cp[j]].
        let csr = suite::quantum_clusters(300, 3, 8, 6, 5);
        let rp = cuthill_mckee(&csr);
        let cp = rp.clone(); // symmetric permutation
        let b = permute(&csr, &rp, &cp);
        let mut rng = Rng::new(1);
        let x: Vec<f64> = (0..csr.cols).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        let xp = permute_vec(&x, &cp);
        let mut y = vec![0.0; csr.rows];
        csr.spmv_ref(&x, &mut y);
        let mut yp = vec![0.0; b.rows];
        b.spmv_ref(&xp, &mut yp);
        for (new_r, &old_r) in rp.perm.iter().enumerate() {
            assert!(
                (yp[new_r] - y[old_r as usize]).abs() < 1e-10,
                "row {new_r}"
            );
        }
    }

    #[test]
    fn rcm_reduces_bandwidth_on_shuffled_band() {
        // Shuffle a banded matrix, then RCM should restore a small
        // bandwidth.
        let band = suite::banded(400, 4, 0.8, 7);
        let mut rng = Rng::new(2);
        let mut perm: Vec<u32> = (0..400).collect();
        for i in (1..400usize).rev() {
            let j = rng.next_below(i + 1);
            perm.swap(i, j);
        }
        let shuffle = Permutation { perm };
        let shuffled = permute(&band, &shuffle, &shuffle);
        assert!(bandwidth(&shuffled) > 100);
        let rcm = cuthill_mckee(&shuffled);
        assert!(rcm.validate());
        let restored = permute(&shuffled, &rcm, &rcm);
        assert!(
            bandwidth(&restored) < 40,
            "bandwidth {} not reduced",
            bandwidth(&restored)
        );
    }

    #[test]
    fn column_pack_improves_fill_on_shuffled_contact() {
        // Destroy column locality of a run-structured matrix, then
        // column_pack should recover a good part of the β(1,8) fill.
        let m = suite::contact_runs(600, 2, 32, 9);
        let mut rng = Rng::new(3);
        let mut perm: Vec<u32> = (0..600).collect();
        for i in (1..600usize).rev() {
            let j = rng.next_below(i + 1);
            perm.swap(i, j);
        }
        let cols = Permutation { perm };
        let rows = Permutation::identity(600);
        let shuffled = permute(&m, &rows, &cols);

        let bs = BlockSize::new(1, 8);
        let fill_orig = block_stats(&m, bs).avg_nnz_per_block;
        let fill_shuf = block_stats(&shuffled, bs).avg_nnz_per_block;
        let cp = column_pack(&shuffled);
        assert!(cp.validate());
        let packed = permute(&shuffled, &rows, &cp);
        let fill_packed = block_stats(&packed, bs).avg_nnz_per_block;
        assert!(fill_shuf < fill_orig * 0.6, "shuffle should hurt fill");
        assert!(
            fill_packed > fill_shuf * 1.5,
            "packing should recover fill: orig {fill_orig:.2} shuffled \
             {fill_shuf:.2} packed {fill_packed:.2}"
        );
    }

    #[test]
    fn rcm_handles_disconnected_graph() {
        // Block-diagonal with two components + isolated vertices.
        let mut coo = Coo::new(10, 10);
        coo.push(0, 1, 1.0);
        coo.push(1, 0, 1.0);
        coo.push(5, 6, 1.0);
        coo.push(6, 5, 1.0);
        let csr = coo.to_csr().unwrap();
        let p = cuthill_mckee(&csr);
        assert!(p.validate());
        assert_eq!(p.perm.len(), 10);
    }
}
