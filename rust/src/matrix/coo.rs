//! Coordinate (COO) sparse matrix — the assembly / interchange format.

use super::{Csr, MatrixError, Result};
use crate::scalar::Scalar;

/// A sparse matrix as unsorted `(row, col, value)` triplets.
///
/// COO is the natural assembly format (FEM codes, generators, file
/// readers all emit triplets); every other format in the crate is
/// produced from it through [`Coo::to_csr`].
#[derive(Clone, Debug, Default)]
pub struct Coo<T: Scalar = f64> {
    pub rows: usize,
    pub cols: usize,
    pub entries: Vec<(u32, u32, T)>,
}

impl<T: Scalar> Coo<T> {
    /// Creates an empty matrix of the given dimensions.
    pub fn new(rows: usize, cols: usize) -> Self {
        Coo { rows, cols, entries: Vec::new() }
    }

    /// Adds one entry. Duplicate `(r, c)` pairs are summed by `to_csr`.
    #[inline]
    pub fn push(&mut self, r: usize, c: usize, v: T) {
        debug_assert!(r < self.rows && c < self.cols);
        self.entries.push((r as u32, c as u32, v));
    }

    /// Number of stored triplets (before duplicate merging).
    pub fn nnz(&self) -> usize {
        self.entries.len()
    }

    /// Validates all indices are in bounds.
    pub fn validate(&self) -> Result<()> {
        for &(r, c, v) in &self.entries {
            if r as usize >= self.rows || c as usize >= self.cols {
                return Err(MatrixError::Invalid(format!(
                    "entry ({r},{c}) out of bounds for {}x{}",
                    self.rows, self.cols
                )));
            }
            if !v.is_finite() {
                return Err(MatrixError::Invalid(format!(
                    "non-finite value at ({r},{c})"
                )));
            }
        }
        Ok(())
    }

    /// Converts to CSR: sorts row-major then column-ascending (the order
    /// the paper's formats require), merging duplicates by addition and
    /// dropping explicit zeros that result from cancellation.
    pub fn to_csr(&self) -> Result<Csr<T>> {
        self.validate()?;
        let mut ents = self.entries.clone();
        ents.sort_unstable_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));

        let mut rowptr = vec![0u32; self.rows + 1];
        let mut colidx: Vec<u32> = Vec::with_capacity(ents.len());
        let mut values: Vec<T> = Vec::with_capacity(ents.len());

        let mut i = 0;
        while i < ents.len() {
            let (r, c, mut v) = ents[i];
            let mut j = i + 1;
            while j < ents.len() && ents[j].0 == r && ents[j].1 == c {
                v += ents[j].2;
                j += 1;
            }
            i = j;
            if v != T::ZERO {
                colidx.push(c);
                values.push(v);
                rowptr[r as usize + 1] += 1;
            }
        }
        for r in 0..self.rows {
            rowptr[r + 1] += rowptr[r];
        }
        Csr::from_raw(self.rows, self.cols, rowptr, colidx, values)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_matrix() {
        let coo: Coo = Coo::new(4, 4);
        let csr = coo.to_csr().unwrap();
        assert_eq!(csr.nnz(), 0);
        assert_eq!(csr.rowptr, vec![0, 0, 0, 0, 0]);
    }

    #[test]
    fn duplicates_are_summed() {
        let mut coo = Coo::new(2, 2);
        coo.push(0, 0, 1.0);
        coo.push(0, 0, 2.0);
        coo.push(1, 1, 5.0);
        let csr = coo.to_csr().unwrap();
        assert_eq!(csr.nnz(), 2);
        assert_eq!(csr.values, vec![3.0, 5.0]);
    }

    #[test]
    fn cancellation_drops_entry() {
        let mut coo = Coo::new(1, 2);
        coo.push(0, 1, 2.0);
        coo.push(0, 1, -2.0);
        let csr = coo.to_csr().unwrap();
        assert_eq!(csr.nnz(), 0);
    }

    #[test]
    fn f32_assembly_works_end_to_end() {
        let mut coo: Coo<f32> = Coo::new(2, 2);
        coo.push(0, 0, 1.5f32);
        coo.push(0, 0, 0.25f32);
        coo.push(1, 0, -2.0f32);
        let csr = coo.to_csr().unwrap();
        assert_eq!(csr.values, vec![1.75f32, -2.0f32]);
    }

    #[test]
    fn unsorted_input_sorted_in_csr() {
        let mut coo = Coo::new(3, 4);
        coo.push(2, 3, 1.0);
        coo.push(0, 2, 2.0);
        coo.push(0, 0, 3.0);
        coo.push(2, 0, 4.0);
        let csr = coo.to_csr().unwrap();
        assert_eq!(csr.rowptr, vec![0, 2, 2, 4]);
        assert_eq!(csr.colidx, vec![0, 2, 0, 3]);
        assert_eq!(csr.values, vec![3.0, 2.0, 4.0, 1.0]);
    }

    #[test]
    fn out_of_bounds_rejected() {
        let coo = Coo { rows: 2, cols: 2, entries: vec![(2, 0, 1.0)] };
        assert!(coo.to_csr().is_err());
    }

    #[test]
    fn non_finite_rejected() {
        let coo = Coo { rows: 1, cols: 1, entries: vec![(0, 0, f64::NAN)] };
        assert!(coo.to_csr().is_err());
    }
}
