//! Compressed Sparse Row (CSR) — the de-facto standard SpMV storage and
//! the paper's baseline format (Fig. 1). Generic over the element
//! precision ([`Scalar`], `f64` by default).

use super::{Dense, MatrixError, Result};
use crate::scalar::Scalar;

/// CSR matrix: `rowptr` (len rows+1), `colidx` + `values` (len nnz),
/// rows stored contiguously with ascending column indices.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Csr<T: Scalar = f64> {
    pub rows: usize,
    pub cols: usize,
    pub rowptr: Vec<u32>,
    pub colidx: Vec<u32>,
    pub values: Vec<T>,
}

/// The strict-lower / diagonal / strict-upper decomposition of a square
/// matrix (`A = L + D + U`), produced by [`Csr::triangular_split`].
/// The triangular-solve kernels ([`crate::kernels::sptrsv`]), the
/// Gauss–Seidel sweeps ([`crate::kernels::symgs`]) and the ILU(0)
/// factorization all operate on this split.
#[derive(Clone, Debug, PartialEq)]
pub struct TriangularSplit<T: Scalar = f64> {
    /// Strict lower triangle (entries with `col < row`), CSR.
    pub lower: Csr<T>,
    /// Diagonal entries; `T::ZERO` where the diagonal is structurally
    /// missing (callers that divide must check — see
    /// [`TriangularSplit::missing_diagonals`]).
    pub diag: Vec<T>,
    /// Strict upper triangle (entries with `col > row`), CSR.
    pub upper: Csr<T>,
}

impl<T: Scalar> TriangularSplit<T> {
    /// Matrix dimension (the split is square by construction).
    pub fn n(&self) -> usize {
        self.diag.len()
    }

    /// Rows whose diagonal entry is structurally missing or stored as
    /// exactly zero — the rows a triangular solve would divide by zero
    /// on.
    pub fn missing_diagonals(&self) -> Vec<usize> {
        self.diag
            .iter()
            .enumerate()
            .filter(|(_, &d)| d == T::ZERO)
            .map(|(r, _)| r)
            .collect()
    }
}

impl<T: Scalar> Csr<T> {
    /// Builds from raw arrays after validating the CSR invariants:
    /// monotone rowptr, in-bounds strictly-ascending columns per row.
    pub fn from_raw(
        rows: usize,
        cols: usize,
        rowptr: Vec<u32>,
        colidx: Vec<u32>,
        values: Vec<T>,
    ) -> Result<Self> {
        if rowptr.len() != rows + 1 {
            return Err(MatrixError::Invalid(format!(
                "rowptr length {} != rows+1 ({})",
                rowptr.len(),
                rows + 1
            )));
        }
        if colidx.len() != values.len() {
            return Err(MatrixError::Invalid(format!(
                "colidx length {} != values length {}",
                colidx.len(),
                values.len()
            )));
        }
        if rowptr[0] != 0 || rowptr[rows] as usize != colidx.len() {
            return Err(MatrixError::Invalid(
                "rowptr does not span [0, nnz]".to_string(),
            ));
        }
        for r in 0..rows {
            let (a, b) = (rowptr[r] as usize, rowptr[r + 1] as usize);
            if b < a {
                return Err(MatrixError::Invalid(format!(
                    "rowptr not monotone at row {r}"
                )));
            }
            let mut prev: i64 = -1;
            for k in a..b {
                let c = colidx[k] as i64;
                if c <= prev {
                    return Err(MatrixError::Invalid(format!(
                        "columns not strictly ascending in row {r}"
                    )));
                }
                if c as usize >= cols {
                    return Err(MatrixError::Invalid(format!(
                        "column {c} out of bounds in row {r}"
                    )));
                }
                prev = c;
            }
        }
        Ok(Csr { rows, cols, rowptr, colidx, values })
    }

    /// Number of stored nonzeros.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Average nonzeros per row (`N_NNZ / N_rows`, Table 1 column 4).
    pub fn nnz_per_row(&self) -> f64 {
        if self.rows == 0 {
            0.0
        } else {
            self.nnz() as f64 / self.rows as f64
        }
    }

    /// The row range `[start, end)` into `colidx`/`values`.
    #[inline]
    pub fn row_range(&self, r: usize) -> std::ops::Range<usize> {
        self.rowptr[r] as usize..self.rowptr[r + 1] as usize
    }

    /// Memory occupancy in bytes per the paper's Eq. (3):
    /// `nnz*(S_int + S_float) + S_int*(rows+1)`, with `S_float` the
    /// size of this precision's element.
    pub fn occupancy_bytes(&self) -> usize {
        self.nnz() * (4 + T::BYTES) + 4 * (self.rows + 1)
    }

    /// Reference sequential SpMV `y += A x` in pure safe Rust. This is
    /// the semantic definition every kernel is tested against.
    pub fn spmv_ref(&self, x: &[T], y: &mut [T]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        for r in 0..self.rows {
            let mut sum = T::ZERO;
            for k in self.row_range(r) {
                sum += self.values[k] * x[self.colidx[k] as usize];
            }
            y[r] += sum;
        }
    }

    /// Materializes as a **widened-to-f64** dense oracle (tests / tiny
    /// matrices only). For `T = f32` this is the differential-testing
    /// reference: the exact f64 product over the f32-truncated values.
    pub fn to_dense(&self) -> Dense {
        let mut d = Dense::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            for k in self.row_range(r) {
                d.set(r, self.colidx[k] as usize, self.values[k].to_f64());
            }
        }
        d
    }

    /// Casts the matrix to another precision (same structure, values
    /// converted through f64). `to_precision::<f32>()` is the entry
    /// point to the 16-lane `β32` stack.
    pub fn to_precision<U: Scalar>(&self) -> Csr<U> {
        Csr {
            rows: self.rows,
            cols: self.cols,
            rowptr: self.rowptr.clone(),
            colidx: self.colidx.clone(),
            values: self.values.iter().map(|&v| U::from_f64(v.to_f64())).collect(),
        }
    }

    /// Extracts the sub-matrix of full rows `[r0, r1)` (used by the
    /// NUMA-split parallel mode to give each thread its own arrays).
    pub fn row_slice(&self, r0: usize, r1: usize) -> Csr<T> {
        assert!(r0 <= r1 && r1 <= self.rows);
        let a = self.rowptr[r0] as usize;
        let b = self.rowptr[r1] as usize;
        let rowptr: Vec<u32> =
            self.rowptr[r0..=r1].iter().map(|&p| p - self.rowptr[r0]).collect();
        Csr {
            rows: r1 - r0,
            cols: self.cols,
            rowptr,
            colidx: self.colidx[a..b].to_vec(),
            values: self.values[a..b].to_vec(),
        }
    }

    /// Splits a square matrix into its strict-lower / diagonal /
    /// strict-upper parts (`A = L + D + U`) in one pass. Columns stay
    /// strictly ascending within each part, so both triangles are valid
    /// CSR by construction. Rejects non-square matrices.
    pub fn triangular_split(&self) -> Result<TriangularSplit<T>> {
        if self.rows != self.cols {
            return Err(MatrixError::Invalid(format!(
                "triangular split needs a square matrix, got {}x{}",
                self.rows, self.cols
            )));
        }
        let n = self.rows;
        let mut lo_rowptr = Vec::with_capacity(n + 1);
        let mut lo_colidx = Vec::new();
        let mut lo_values = Vec::new();
        let mut up_rowptr = Vec::with_capacity(n + 1);
        let mut up_colidx = Vec::new();
        let mut up_values = Vec::new();
        let mut diag = vec![T::ZERO; n];
        lo_rowptr.push(0);
        up_rowptr.push(0);
        for r in 0..n {
            for k in self.row_range(r) {
                let c = self.colidx[k] as usize;
                match c.cmp(&r) {
                    std::cmp::Ordering::Less => {
                        lo_colidx.push(c as u32);
                        lo_values.push(self.values[k]);
                    }
                    std::cmp::Ordering::Equal => diag[r] = self.values[k],
                    std::cmp::Ordering::Greater => {
                        up_colidx.push(c as u32);
                        up_values.push(self.values[k]);
                    }
                }
            }
            lo_rowptr.push(lo_colidx.len() as u32);
            up_rowptr.push(up_colidx.len() as u32);
        }
        Ok(TriangularSplit {
            lower: Csr {
                rows: n,
                cols: n,
                rowptr: lo_rowptr,
                colidx: lo_colidx,
                values: lo_values,
            },
            diag,
            upper: Csr {
                rows: n,
                cols: n,
                rowptr: up_rowptr,
                colidx: up_colidx,
                values: up_values,
            },
        })
    }

    /// Transposes the matrix (CSR → CSR of the transpose). Used by
    /// generators to symmetrize patterns.
    pub fn transpose(&self) -> Csr<T> {
        let mut rowptr = vec![0u32; self.cols + 1];
        for &c in &self.colidx {
            rowptr[c as usize + 1] += 1;
        }
        for c in 0..self.cols {
            rowptr[c + 1] += rowptr[c];
        }
        let mut colidx = vec![0u32; self.nnz()];
        let mut values = vec![T::ZERO; self.nnz()];
        let mut next = rowptr.clone();
        for r in 0..self.rows {
            for k in self.row_range(r) {
                let c = self.colidx[k] as usize;
                let dst = next[c] as usize;
                colidx[dst] = r as u32;
                values[dst] = self.values[k];
                next[c] += 1;
            }
        }
        Csr { rows: self.cols, cols: self.rows, rowptr, colidx, values }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The 8×8 example from the paper's Fig. 1.
    pub fn paper_fig1() -> Csr {
        let rowptr = vec![0, 4, 7, 10, 12, 14, 14, 15, 18];
        let colidx = vec![0, 1, 4, 6, 1, 2, 3, 2, 4, 6, 3, 4, 5, 6, 5, 0, 4, 7];
        let values: Vec<f64> = (1..=18).map(|v| v as f64).collect();
        Csr::from_raw(8, 8, rowptr, colidx, values).unwrap()
    }

    #[test]
    fn fig1_matrix_valid() {
        let m = paper_fig1();
        assert_eq!(m.nnz(), 18);
        assert_eq!(m.row_range(5), 14..14); // empty row 5, like the paper
    }

    #[test]
    fn spmv_matches_dense() {
        let m = paper_fig1();
        let x: Vec<f64> = (0..8).map(|i| 0.5 + i as f64).collect();
        let mut y = vec![0.0; 8];
        m.spmv_ref(&x, &mut y);
        let d = m.to_dense();
        let yd = d.matvec(&x);
        for (a, b) in y.iter().zip(&yd) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn occupancy_eq3() {
        let m = paper_fig1();
        // 18*(4+8) + 4*9 = 216 + 36 = 252
        assert_eq!(m.occupancy_bytes(), 252);
        // f32: values halve, indices stay.
        assert_eq!(m.to_precision::<f32>().occupancy_bytes(), 18 * 8 + 36);
    }

    #[test]
    fn precision_cast_preserves_structure() {
        let m = paper_fig1();
        let m32: Csr<f32> = m.to_precision();
        assert_eq!(m32.rowptr, m.rowptr);
        assert_eq!(m32.colidx, m.colidx);
        assert_eq!(m32.values[4], 5.0f32);
        // Round trip through f32 is exact for these small integers.
        assert_eq!(m32.to_precision::<f64>(), m);
    }

    #[test]
    fn f32_spmv_ref_matches_widened_dense() {
        let m32: Csr<f32> = paper_fig1().to_precision();
        let x32: Vec<f32> = (0..8).map(|i| 0.25 * i as f32 - 1.0).collect();
        let mut y32 = vec![0.0f32; 8];
        m32.spmv_ref(&x32, &mut y32);
        let x64: Vec<f64> = x32.iter().map(|&v| v as f64).collect();
        let want = m32.to_dense().matvec(&x64);
        for i in 0..8 {
            assert!((y32[i] as f64 - want[i]).abs() < 1e-5, "row {i}");
        }
    }

    #[test]
    fn invalid_rowptr_rejected() {
        assert!(Csr::<f64>::from_raw(2, 2, vec![0, 1], vec![0], vec![1.0])
            .is_err());
        assert!(Csr::<f64>::from_raw(
            2,
            2,
            vec![0, 2, 1],
            vec![0, 1],
            vec![1.0, 2.0]
        )
        .is_err());
        assert!(Csr::<f64>::from_raw(1, 1, vec![1, 1], vec![], vec![]).is_err());
    }

    #[test]
    fn non_ascending_columns_rejected() {
        assert!(Csr::<f64>::from_raw(1, 4, vec![0, 2], vec![2, 1], vec![
            1.0, 2.0
        ])
        .is_err());
        // duplicate column
        assert!(Csr::<f64>::from_raw(1, 4, vec![0, 2], vec![1, 1], vec![
            1.0, 2.0
        ])
        .is_err());
    }

    #[test]
    fn out_of_bounds_column_rejected() {
        assert!(Csr::<f64>::from_raw(1, 2, vec![0, 1], vec![5], vec![1.0])
            .is_err());
    }

    #[test]
    fn row_slice_preserves_rows() {
        let m = paper_fig1();
        let s = m.row_slice(2, 5);
        assert_eq!(s.rows, 3);
        assert_eq!(s.nnz(), (m.rowptr[5] - m.rowptr[2]) as usize);
        let x: Vec<f64> = (0..8).map(|i| i as f64 * 0.1).collect();
        let mut y_full = vec![0.0; 8];
        m.spmv_ref(&x, &mut y_full);
        let mut y_slice = vec![0.0; 3];
        s.spmv_ref(&x, &mut y_slice);
        for i in 0..3 {
            assert!((y_full[2 + i] - y_slice[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn triangular_split_partitions_every_entry() {
        let m = paper_fig1();
        let s = m.triangular_split().unwrap();
        // Every nonzero lands in exactly one part.
        let diag_nnz = s.diag.iter().filter(|&&d| d != 0.0).count();
        assert_eq!(s.lower.nnz() + diag_nnz + s.upper.nnz(), m.nnz());
        // L + D + U reassembles A exactly.
        let d = m.to_dense();
        let (dl, du) = (s.lower.to_dense(), s.upper.to_dense());
        for r in 0..8 {
            for c in 0..8 {
                let mut v = dl.get(r, c) + du.get(r, c);
                if r == c {
                    v += s.diag[r];
                }
                assert_eq!(v, d.get(r, c), "({r},{c})");
            }
        }
        // Strictness: no diagonal entries in either triangle.
        for r in 0..8 {
            for k in s.lower.row_range(r) {
                assert!((s.lower.colidx[k] as usize) < r);
            }
            for k in s.upper.row_range(r) {
                assert!((s.upper.colidx[k] as usize) > r);
            }
        }
        // Fig. 1 rows 4, 6 and the empty row 5 have no diagonal entry.
        assert_eq!(s.missing_diagonals(), vec![4, 5, 6]);
    }

    #[test]
    fn triangular_split_rejects_rectangular() {
        let m = Csr::<f64>::from_raw(1, 2, vec![0, 1], vec![1], vec![2.0])
            .unwrap();
        assert!(m.triangular_split().is_err());
    }

    #[test]
    fn transpose_twice_is_identity() {
        let m = paper_fig1();
        let tt = m.transpose().transpose();
        assert_eq!(m, tt);
    }

    #[test]
    fn transpose_matches_dense() {
        let m = paper_fig1();
        let t = m.transpose();
        let d = m.to_dense();
        for r in 0..8 {
            for c in 0..8 {
                assert_eq!(d.get(r, c), t.to_dense().get(c, r));
            }
        }
    }
}
