//! Synthetic benchmark suite — surrogates for the paper's SuiteSparse
//! matrix sets (Table 1 "Set-A", Table 2 "Set-B").
//!
//! The container has no network access to fetch SuiteSparse, so each
//! benchmark matrix is replaced by a deterministic generator that
//! reproduces its *structural class* — the property the SPC5 kernels
//! are sensitive to: the average number of nonzeros per `β(r,c)` block
//! and the access pattern on `x`. Dimensions are scaled down (~10–30×)
//! so the full table regenerates in minutes on the 1-core host; nnz/row
//! and the block-fill profile are preserved, and the per-matrix stats
//! table (our Table 1/2 analogue) is printed next to the paper's values
//! by `cargo bench --bench table1_stats`.
//!
//! Structural classes used (see DESIGN.md §3):
//! - 3D stencils (`atmosmodd`) — 7-point Laplacian, short diagonal runs.
//! - node-blocked FEM (`bone010`, `ldoor`, `pwtk`, Set-B geomechanics) —
//!   dense `dof×dof` blocks on a node graph → highly filled blocks.
//! - post-optimization / contact problems (`nd6k`, `pdb1HYS`, `torso1`,
//!   `mip1`, `crankseg`) — long contiguous row runs → fill ≥ 75%.
//! - quantum chemistry (`Ga19As19H42`, `Si*`, `CO`) — clustered columns
//!   with scattered fringe → fill ~20–45%.
//! - circuit / network (`rajat31`, `circuit5M`, `FullChip`) — strong
//!   diagonal + a few random entries + a handful of dense rows.
//! - web graphs (`in-2004`, `indochina-2004`) — power-law with host
//!   locality (contiguous runs); (`wikipedia`) — power-law without
//!   locality.
//! - Kronecker graph (`kron_g500-logn21`) — RMAT, worst-case fill ≈ 1.
//! - uniform scatter (`ns3Da`, `cage15`) — random columns, fill ≈ 1.
//! - dense (`Dense-8000` → Dense-2000 surrogate).
//!
//! Generators always assemble in f64 (deterministic double values);
//! drive the single-precision (`β32`) stack by casting afterwards
//! with [`Csr::to_precision`].

use super::{Coo, Csr};
use crate::util::Rng;

/// A named suite matrix.
pub struct SuiteMatrix {
    pub name: &'static str,
    /// Structural class of the paper matrix this stands in for.
    pub class: &'static str,
    pub csr: Csr,
}

/// Generator: 3D `nx×ny×nz` 7-point stencil (atmosmodd class).
pub fn stencil3d(nx: usize, ny: usize, nz: usize) -> Csr {
    let n = nx * ny * nz;
    let idx = |x: usize, y: usize, z: usize| (z * ny + y) * nx + x;
    let mut coo = Coo::new(n, n);
    let mut rng = Rng::new(0x57E7C11);
    for z in 0..nz {
        for y in 0..ny {
            for x in 0..nx {
                let r = idx(x, y, z);
                coo.push(r, r, 6.0 + rng.next_f64());
                if x > 0 {
                    coo.push(r, idx(x - 1, y, z), -1.0 - rng.next_f64() * 0.1);
                }
                if x + 1 < nx {
                    coo.push(r, idx(x + 1, y, z), -1.0 - rng.next_f64() * 0.1);
                }
                if y > 0 {
                    coo.push(r, idx(x, y - 1, z), -1.0);
                }
                if y + 1 < ny {
                    coo.push(r, idx(x, y + 1, z), -1.0);
                }
                if z > 0 {
                    coo.push(r, idx(x, y, z - 1), -1.0);
                }
                if z + 1 < nz {
                    coo.push(r, idx(x, y, z + 1), -1.0);
                }
            }
        }
    }
    coo.to_csr().expect("stencil3d produces valid matrices")
}

/// Generator: 2D 5-point Laplacian on an `n×n` grid (SPD; used by the
/// CG example and tests).
pub fn poisson2d(n: usize) -> Csr {
    let dim = n * n;
    let idx = |x: usize, y: usize| y * n + x;
    let mut coo = Coo::new(dim, dim);
    for y in 0..n {
        for x in 0..n {
            let r = idx(x, y);
            coo.push(r, r, 4.0);
            if x > 0 {
                coo.push(r, idx(x - 1, y), -1.0);
            }
            if x + 1 < n {
                coo.push(r, idx(x + 1, y), -1.0);
            }
            if y > 0 {
                coo.push(r, idx(x, y - 1), -1.0);
            }
            if y + 1 < n {
                coo.push(r, idx(x, y + 1), -1.0);
            }
        }
    }
    coo.to_csr().expect("poisson2d produces valid matrices")
}

/// Generator: node-blocked FEM matrix. `nodes` mesh nodes with `dof`
/// unknowns each; each node couples to a *contiguous* run of
/// neighbouring nodes (mesh locality after bandwidth-reducing
/// ordering) plus a few remote nodes, every coupling a dense `dof×dof`
/// block (bone010/ldoor class → highly filled β blocks, including the
/// tall ones — all `dof` rows of a node share the same column runs).
pub fn fem_blocked(nodes: usize, dof: usize, deg: usize, seed: u64) -> Csr {
    let n = nodes * dof;
    let mut rng = Rng::new(seed);
    let mut coo = Coo::new(n, n);
    for node in 0..nodes {
        // A contiguous neighbourhood: self ± a small run (most of the
        // stencil), plus remote couplings for the rest of `deg`.
        let run = 1 + deg / 3; // nodes on each side
        let lo = node.saturating_sub(run);
        let hi = (node + run).min(nodes - 1);
        let mut neigh: Vec<usize> = (lo..=hi).collect();
        for _ in 0..deg.saturating_sub(2 * run) {
            let span = 8 + rng.next_below(nodes.min(256));
            let cand = if rng.chance(0.5) {
                node.saturating_sub(span)
            } else {
                (node + span).min(nodes - 1)
            };
            neigh.push(cand);
        }
        neigh.sort_unstable();
        neigh.dedup();
        for &m in &neigh {
            for i in 0..dof {
                for j in 0..dof {
                    // ~12% in-block dropout keeps the fill below 100%,
                    // like real assembled FEM couplings.
                    if node != m && rng.chance(0.12) {
                        continue;
                    }
                    let v = if node == m && i == j {
                        4.0 * deg as f64 + rng.next_f64()
                    } else {
                        rng.nnz_value() * 0.5
                    };
                    coo.push(node * dof + i, m * dof + j, v);
                }
            }
        }
    }
    coo.to_csr().expect("fem_blocked produces valid matrices")
}

/// Generator: contact/optimization class — each row is a few long
/// contiguous runs with light dropout (nd6k / pdb1HYS / torso1 / mip1):
/// fill ≈ 80% at `β(1,8)`. Runs are shared across groups of 8
/// consecutive rows (contact patches touch row *bands*), so tall
/// blocks stay filled too, as in the paper's Table 1.
pub fn contact_runs(
    n: usize,
    runs_per_row: usize,
    run_len: usize,
    seed: u64,
) -> Csr {
    let mut rng = Rng::new(seed);
    let mut coo = Coo::new(n, n);
    const GROUP: usize = 8;
    let mut remote_starts: Vec<usize> = Vec::new();
    for r in 0..n {
        if r % GROUP == 0 {
            // New row band: fresh remote contact patches.
            remote_starts.clear();
            for _ in 1..runs_per_row {
                let center = rng.next_below(n);
                remote_starts.push(center.saturating_sub(run_len / 2));
            }
        }
        let mut starts = vec![r.saturating_sub(run_len / 2)];
        starts.extend_from_slice(&remote_starts);
        for s in starts {
            let s = s.min(n.saturating_sub(run_len));
            for c in s..(s + run_len).min(n) {
                // ~20% dropout: contact patches are dense but not full.
                if rng.chance(0.8) {
                    coo.push(r, c, rng.nnz_value());
                }
            }
        }
    }
    coo.to_csr().expect("contact_runs produces valid matrices")
}

/// Generator: quantum-chemistry class — clustered column groups of
/// width `cluster` with probability-decaying membership plus a
/// scattered fringe (Ga19As19H42 / Si* / CO): fill ~20–45%.
pub fn quantum_clusters(
    n: usize,
    clusters_per_row: usize,
    cluster: usize,
    fringe: usize,
    seed: u64,
) -> Csr {
    let mut rng = Rng::new(seed);
    let mut coo = Coo::new(n, n);
    const GROUP: usize = 4; // orbitals of one atom share couplings
    let mut starts: Vec<usize> = Vec::new();
    for r in 0..n {
        if r % GROUP == 0 {
            starts.clear();
            for _ in 0..clusters_per_row {
                starts.push(rng.next_below(n.saturating_sub(cluster).max(1)));
            }
        }
        for &start in &starts {
            for c in start..(start + cluster).min(n) {
                // ~55% membership: clusters are dense-ish but not full.
                if rng.chance(0.55) {
                    coo.push(r, c, rng.nnz_value());
                }
            }
        }
        for _ in 0..fringe {
            coo.push(r, rng.next_below(n), rng.nnz_value());
        }
        coo.push(r, r, 2.0 + rng.next_f64()); // diagonal
    }
    coo.to_csr().expect("quantum_clusters produces valid matrices")
}

/// Generator: circuit class — unit diagonal, `avg_off` random
/// off-diagonals per row with geometric locality, and a few dense rows
/// (power rails), rajat31 / circuit5M / FullChip.
pub fn circuit(n: usize, avg_off: usize, dense_rows: usize, seed: u64) -> Csr {
    let mut rng = Rng::new(seed);
    let mut coo = Coo::new(n, n);
    for r in 0..n {
        coo.push(r, r, 1.0 + rng.next_f64());
        for _ in 0..avg_off {
            // Mix of near-diagonal (local wires) and far (global nets).
            let c = if rng.chance(0.7) {
                let span = 1 + rng.next_below(32);
                if rng.chance(0.5) {
                    r.saturating_sub(span)
                } else {
                    (r + span).min(n - 1)
                }
            } else {
                rng.next_below(n)
            };
            if c != r {
                coo.push(r, c, rng.nnz_value());
                // Two-terminal stamps touch column pairs and the next
                // row symmetrically about half the time.
                if rng.chance(0.4) && c + 1 < n {
                    coo.push(r, c + 1, rng.nnz_value());
                }
                if rng.chance(0.3) && r + 1 < n {
                    coo.push(r + 1, c, rng.nnz_value());
                }
            }
        }
    }
    for _ in 0..dense_rows {
        let r = rng.next_below(n);
        let stride = (n / 2048).max(1);
        let mut c = rng.next_below(stride);
        while c < n {
            coo.push(r, c, rng.nnz_value() * 0.01);
            c += stride + rng.next_below(stride.max(1));
        }
    }
    coo.to_csr().expect("circuit produces valid matrices")
}

/// Generator: web-graph class — power-law out-degree with host
/// locality: a fraction `local` of the links point to a contiguous
/// same-host window (runs), the rest are global (in-2004 /
/// indochina-2004; `local=0` gives the wikipedia class).
pub fn webgraph(n: usize, avg_deg: usize, local: f64, seed: u64) -> Csr {
    let mut rng = Rng::new(seed);
    let mut coo = Coo::new(n, n);
    // Pages of a host share their navigation-bar targets: runs are
    // drawn per 4-page group, giving the vertical correlation that
    // makes tall blocks viable on in-2004/indochina (paper Table 1).
    const GROUP: usize = 4;
    let mut nav_runs: Vec<(usize, usize)> = Vec::new();
    for r in 0..n {
        let host_start = (r / 64) * 64; // 64-page "host" window
        if r % GROUP == 0 {
            nav_runs.clear();
            for _ in 0..3 {
                let start = host_start + rng.next_below(56);
                let len = 2 + rng.next_below(7);
                nav_runs.push((start, len));
            }
        }
        // Power-law degree: deg = avg_deg * (u^-0.45), clamped.
        let u = rng.next_f64().max(1e-6);
        let deg =
            ((avg_deg as f64 * u.powf(-0.45) * 0.55) as usize).clamp(1, n / 4);
        let mut emitted = 0;
        let mut nav = 0usize;
        while emitted < deg {
            if rng.chance(local) {
                // Shared nav-bar run (cycled), lightly perturbed.
                let (start, len) = nav_runs[nav % nav_runs.len()];
                nav += 1;
                for k in 0..len {
                    let c = start + k;
                    if c < n && rng.chance(0.9) {
                        coo.push(r, c, 1.0 + rng.next_f64());
                        emitted += 1;
                    }
                }
            } else {
                coo.push(r, rng.next_below(n), 1.0 + rng.next_f64());
                emitted += 1;
            }
        }
    }
    coo.to_csr().expect("webgraph produces valid matrices")
}

/// Generator: RMAT / Kronecker graph (kron_g500 class — the worst case
/// for blocking: Avg(r,c) ≈ 1 for every block size).
pub fn rmat(scale: u32, edge_factor: usize, seed: u64) -> Csr {
    let n = 1usize << scale;
    let edges = n * edge_factor;
    let (a, b, c) = (0.57, 0.19, 0.19); // Graph500 parameters
    let mut rng = Rng::new(seed);
    let mut coo = Coo::new(n, n);
    for _ in 0..edges {
        let (mut r, mut cc) = (0usize, 0usize);
        for level in (0..scale).rev() {
            let p = rng.next_f64();
            let (ri, ci) = if p < a {
                (0, 0)
            } else if p < a + b {
                (0, 1)
            } else if p < a + b + c {
                (1, 0)
            } else {
                (1, 1)
            };
            r |= ri << level;
            cc |= ci << level;
        }
        coo.push(r, cc, 1.0 + rng.next_f64());
    }
    coo.to_csr().expect("rmat produces valid matrices")
}

/// Generator: uniform scatter — `deg` uniformly random columns per row
/// (ns3Da / cage15 class: blocks stay almost empty).
pub fn uniform_scatter(n: usize, deg: usize, seed: u64) -> Csr {
    let mut rng = Rng::new(seed);
    let mut coo = Coo::new(n, n);
    for r in 0..n {
        for _ in 0..deg {
            coo.push(r, rng.next_below(n), rng.nnz_value());
        }
        coo.push(r, r, deg as f64);
    }
    coo.to_csr().expect("uniform_scatter produces valid matrices")
}

/// Generator: structurally heterogeneous square matrix — the top half
/// is a densely filled band (block-friendly, high `Avg(r,c)`), the
/// bottom half uniform scatter (blocks stay nearly empty, CSR
/// territory). No fixed whole-matrix kernel is right for both halves;
/// this is the motivating case for the per-panel hybrid schedule.
pub fn mixed_band_scatter(n: usize, seed: u64) -> Csr {
    let mut rng = Rng::new(seed);
    let mut coo = Coo::new(n, n);
    let half = n / 2;
    for r in 0..half {
        let lo = r.saturating_sub(12);
        let hi = (r + 12).min(n - 1);
        for c in lo..=hi {
            coo.push(r, c, rng.nnz_value());
        }
    }
    for r in half..n {
        coo.push(r, r, 4.0 + rng.next_f64());
        for _ in 0..6 {
            coo.push(r, rng.next_below(n), rng.nnz_value());
        }
    }
    coo.to_csr().expect("mixed_band_scatter produces valid matrices")
}

/// Generator: wide scatter — a column space chosen far larger than any
/// LLC share, so the `x` working set (`cols · 8` bytes at f64) cannot
/// stay cache-resident across a flat SpMV. Each row mixes one short
/// contiguous run (so β blocks exist and the block kernels are
/// actually exercised) with uniformly random far columns (the loads
/// that miss once `x` spills). This is the matrix class where
/// column-tiled execution pays; flat-`x`-traffic generators hide it.
/// Deterministic: the seed is derived from the shape.
pub fn wide_random(rows: usize, cols: usize, nnz_per_row: usize) -> Csr {
    let seed = 0x71DE_0000_u64
        ^ (rows as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ (cols as u64).rotate_left(17)
        ^ (nnz_per_row as u64).rotate_left(41);
    let mut rng = Rng::new(seed);
    let mut coo = Coo::new(rows, cols);
    let run = nnz_per_row.min(3).max(1);
    for r in 0..rows {
        let start = rng.next_below(cols.saturating_sub(run).max(1));
        for c in start..(start + run).min(cols) {
            coo.push(r, c, rng.nnz_value());
        }
        for _ in 0..nnz_per_row.saturating_sub(run) {
            coo.push(r, rng.next_below(cols), rng.nnz_value());
        }
    }
    coo.to_csr().expect("wide_random produces valid matrices")
}

/// Generator: dense matrix (Dense-8000 surrogate, scaled).
pub fn dense(n: usize, seed: u64) -> Csr {
    let mut rng = Rng::new(seed);
    let mut coo = Coo::new(n, n);
    for r in 0..n {
        for c in 0..n {
            coo.push(r, c, rng.nnz_value());
        }
    }
    coo.to_csr().expect("dense produces valid matrices")
}

/// Generator: rectangular LP-style matrix with long runs (spal_004
/// class: rows ≪ cols, high fill at `β(1,8)` but poor at tall blocks).
pub fn rect_runs(
    rows: usize,
    cols: usize,
    runs_per_row: usize,
    run_len: usize,
    seed: u64,
) -> Csr {
    let mut rng = Rng::new(seed);
    let mut coo = Coo::new(rows, cols);
    for r in 0..rows {
        for _ in 0..runs_per_row {
            let s = rng.next_below(cols.saturating_sub(run_len).max(1));
            for c in s..(s + run_len).min(cols) {
                coo.push(r, c, rng.nnz_value());
            }
        }
    }
    coo.to_csr().expect("rect_runs produces valid matrices")
}

/// Generator: banded matrix with partial fill inside the band
/// (dielFilter class: moderate fill that does not grow with block size).
pub fn banded(n: usize, half_bw: usize, fill: f64, seed: u64) -> Csr {
    let mut rng = Rng::new(seed);
    let mut coo = Coo::new(n, n);
    for r in 0..n {
        coo.push(r, r, 4.0 + rng.next_f64());
        let lo = r.saturating_sub(half_bw);
        let hi = (r + half_bw).min(n - 1);
        for c in lo..=hi {
            if c != r && rng.chance(fill) {
                coo.push(r, c, rng.nnz_value());
            }
        }
    }
    coo.to_csr().expect("banded produces valid matrices")
}

/// Scale factor applied to the paper's matrix dimensions so the suite
/// runs in minutes on the single-core container. Recorded in
/// EXPERIMENTS.md.
pub const SCALE_NOTE: &str =
    "dimensions scaled ~10-30x down vs the paper; nnz/row and block-fill \
     profiles preserved";

fn m(name: &'static str, class: &'static str, csr: Csr) -> SuiteMatrix {
    SuiteMatrix { name, class, csr }
}

/// Set-A surrogates (paper Table 1). Order matches the paper.
pub fn set_a() -> Vec<SuiteMatrix> {
    vec![
        m("atmosmodd", "stencil3d", stencil3d(48, 48, 48)),
        m(
            "Ga19As19H42",
            "quantum",
            quantum_clusters(12_000, 5, 14, 14, 0xA11CE),
        ),
        m("mip1", "contact", contact_runs(7_000, 3, 48, 0xB0B)),
        m("rajat31", "circuit", circuit(160_000, 3, 12, 0xC1AC)),
        m("bone010", "fem", fem_blocked(24_000, 3, 7, 0xB0E)),
        m("HV15R", "cfd-blocked", fem_blocked(18_000, 5, 5, 0xCFD)),
        m(
            "mixtank_new",
            "quantum",
            quantum_clusters(6_000, 6, 10, 18, 0x717A),
        ),
        m(
            "Si41Ge41H72",
            "quantum",
            quantum_clusters(14_000, 6, 14, 12, 0x5141),
        ),
        m("cage15", "scatter-local", webgraph(90_000, 19, 0.25, 0xCA6E)),
        m("in-2004", "webgraph", webgraph(60_000, 12, 0.72, 0x12004)),
        m("nd6k", "contact", contact_runs(4_000, 4, 80, 0x6D6)),
        m(
            "Si87H76",
            "quantum",
            quantum_clusters(16_000, 4, 12, 16, 0x5876),
        ),
        m("circuit5M", "circuit", circuit(140_000, 7, 20, 0xC513)),
        m("indochina-2004", "webgraph", webgraph(80_000, 26, 0.78, 0x1D0C)),
        m("ns3Da", "scatter", uniform_scatter(10_000, 81, 0x3DA)),
        m("CO", "quantum", quantum_clusters(12_000, 4, 10, 14, 0xC0)),
        m("kron_g500-logn21", "rmat", rmat(15, 40, 0x6500)),
        m("pdb1HYS", "contact", contact_runs(6_000, 3, 56, 0x1975)),
        m("torso1", "contact", contact_runs(8_000, 3, 48, 0x70450)),
        m("crankseg_2", "contact", contact_runs(7_000, 5, 60, 0xC2A2)),
        m("ldoor", "fem", fem_blocked(30_000, 3, 8, 0x1D002)),
        m("pwtk", "fem", fem_blocked(20_000, 3, 9, 0x9071)),
        m("Dense-8000", "dense", dense(1_400, 0xDE2E)),
    ]
}

/// Set-B surrogates (paper Table 2) — the independent evaluation set
/// for the predictor.
pub fn set_b() -> Vec<SuiteMatrix> {
    vec![
        m("bundle_adj", "contact", contact_runs(9_000, 2, 44, 0xB1D1)),
        m("Cube_Coup_dt0", "fem", fem_blocked(26_000, 3, 10, 0xCBE)),
        m("dielFilterV2real", "banded", banded(40_000, 24, 0.12, 0xD1E1)),
        m("Emilia_923", "fem", fem_blocked(22_000, 3, 7, 0xE923)),
        m("FullChip", "circuit", circuit(120_000, 5, 16, 0xF0C1)),
        m("Hook_1498", "fem", fem_blocked(24_000, 3, 7, 0x1498)),
        m(
            "RM07R",
            "cfd-blocked",
            fem_blocked(12_000, 4, 6, 0x2407),
        ),
        m("Serena", "fem", fem_blocked(25_000, 3, 8, 0x5E2E)),
        m("spal_004", "rect", rect_runs(1_200, 38_000, 6, 160, 0x59A1)),
        m(
            "TSOPF_RS_b2383_c1",
            "contact",
            contact_runs(5_000, 4, 96, 0x7504),
        ),
        m("wikipedia-20060925", "rmat", rmat(15, 12, 0x71C1)),
    ]
}

/// Looks up one suite matrix by (case-insensitive) name across both sets.
pub fn by_name(name: &str) -> Option<SuiteMatrix> {
    let want = name.to_ascii_lowercase();
    set_a()
        .into_iter()
        .chain(set_b())
        .find(|s| s.name.to_ascii_lowercase() == want)
}

/// The small fast subset used by integration tests (keeps `cargo test`
/// quick while covering every structural class).
pub fn test_subset() -> Vec<SuiteMatrix> {
    vec![
        m("stencil-small", "stencil3d", stencil3d(12, 12, 12)),
        m("fem-small", "fem", fem_blocked(800, 3, 6, 1)),
        m("contact-small", "contact", contact_runs(600, 3, 40, 2)),
        m("quantum-small", "quantum", quantum_clusters(700, 4, 12, 10, 3)),
        m("circuit-small", "circuit", circuit(2_000, 3, 4, 4)),
        m("web-small", "webgraph", webgraph(1_500, 10, 0.7, 5)),
        m("rmat-small", "rmat", rmat(9, 12, 6)),
        m("scatter-small", "scatter", uniform_scatter(700, 20, 7)),
        m("dense-small", "dense", dense(96, 8)),
        m("rect-small", "rect", rect_runs(80, 2_000, 4, 60, 9)),
        m("banded-small", "banded", banded(900, 12, 0.15, 10)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generators_are_deterministic() {
        let a = fem_blocked(200, 3, 5, 42);
        let b = fem_blocked(200, 3, 5, 42);
        assert_eq!(a, b);
        let c = fem_blocked(200, 3, 5, 43);
        assert_ne!(a, c);
    }

    #[test]
    fn stencil3d_has_seven_point_rows() {
        let s = stencil3d(6, 6, 6);
        assert_eq!(s.rows, 216);
        // interior point has 7 nnz
        let interior = (3 * 6 + 3) * 6 + 3;
        assert_eq!(s.row_range(interior).len(), 7);
        // corner has 4
        assert_eq!(s.row_range(0).len(), 4);
    }

    #[test]
    fn poisson2d_is_symmetric_diag_dominant() {
        let p = poisson2d(8);
        let d = p.to_dense();
        for r in 0..p.rows {
            for c in 0..p.cols {
                assert_eq!(d.get(r, c), d.get(c, r));
            }
            let offsum: f64 = (0..p.cols)
                .filter(|&c| c != r)
                .map(|c| d.get(r, c).abs())
                .sum();
            assert!(d.get(r, r) >= offsum);
        }
    }

    #[test]
    fn fem_blocked_dims() {
        let f = fem_blocked(100, 3, 5, 7);
        assert_eq!(f.rows, 300);
        assert!(f.nnz() >= 100 * 9); // at least the diagonal blocks
    }

    #[test]
    fn dense_is_full() {
        let d = dense(10, 3);
        assert_eq!(d.nnz(), 100);
    }

    #[test]
    fn wide_random_shape_and_determinism() {
        let a = wide_random(64, 50_000, 8);
        assert_eq!(a.rows, 64);
        assert_eq!(a.cols, 50_000);
        // Duplicate random columns may merge: nnz is bounded, not exact.
        assert!(a.nnz() > 64 * 4 && a.nnz() <= 64 * 8);
        assert_eq!(a, wide_random(64, 50_000, 8));
        assert_ne!(a, wide_random(64, 50_000, 7));
        // Columns genuinely span the wide space (tiling is exercised).
        let max_col = a.colidx.iter().copied().max().unwrap() as usize;
        assert!(max_col > 25_000, "columns should spread wide: {max_col}");
    }

    #[test]
    fn rect_runs_is_rectangular() {
        let r = rect_runs(10, 500, 2, 30, 1);
        assert_eq!(r.rows, 10);
        assert_eq!(r.cols, 500);
        assert!(r.nnz() > 0);
    }

    #[test]
    fn rmat_dims_power_of_two() {
        let g = rmat(8, 8, 5);
        assert_eq!(g.rows, 256);
        assert!(g.nnz() > 0 && g.nnz() <= 256 * 8);
    }

    #[test]
    fn suite_names_unique() {
        let mut names: Vec<&str> =
            set_a().iter().map(|s| s.name).collect::<Vec<_>>();
        names.extend(set_b().iter().map(|s| s.name));
        let n = names.len();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), n);
    }

    #[test]
    fn by_name_finds_case_insensitive() {
        assert!(by_name("ND6K").is_some());
        assert!(by_name("serena").is_some());
        assert!(by_name("not-a-matrix").is_none());
    }

    #[test]
    fn test_subset_covers_classes() {
        let classes: std::collections::BTreeSet<&str> =
            test_subset().iter().map(|s| s.class).collect();
        assert!(classes.len() >= 10);
    }

    #[test]
    fn webgraph_locality_raises_run_length() {
        // With high locality the number of column-adjacent pairs should
        // clearly exceed the no-locality variant.
        let adj_pairs = |m: &Csr| {
            let mut pairs = 0usize;
            for r in 0..m.rows {
                let rr = m.row_range(r);
                for k in rr.start..rr.end.saturating_sub(1) {
                    if m.colidx[k + 1] == m.colidx[k] + 1 {
                        pairs += 1;
                    }
                }
            }
            pairs
        };
        let local = webgraph(2_000, 12, 0.8, 11);
        let global = webgraph(2_000, 12, 0.0, 11);
        assert!(
            adj_pairs(&local) > adj_pairs(&global) * 3,
            "locality should create contiguous runs"
        );
    }
}
