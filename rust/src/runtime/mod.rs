//! XLA/PJRT runtime — loads the AOT artifacts produced by
//! `python/compile/aot.py` (HLO **text**; see /opt/xla-example) and
//! executes them from the Rust request path. Python never runs here.

pub mod artifact;
pub mod executor;

pub use artifact::{Manifest, Workload};
pub use executor::{Executor, XlaEngine};

/// Strip granularity of the Pallas kernel's block descriptors — must
/// match `STRIP` in `python/compile/kernels/spmv_block.py`.
pub const STRIP: usize = 256;
