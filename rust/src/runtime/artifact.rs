//! Artifact manifest: shapes and file names the AOT pass recorded.
//!
//! The Rust side validates its own matrix conversion against these
//! before feeding an executable — a mismatch (e.g. the Python and Rust
//! β conversions disagreeing on nnz) fails loudly instead of producing
//! silent garbage.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One AOT-compiled workload.
#[derive(Clone, Debug, PartialEq)]
pub struct Workload {
    pub name: String,
    pub file: PathBuf,
    pub rows: usize,
    pub cols: usize,
    pub nnz: usize,
    pub iters: Option<usize>,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub strip: usize,
    pub workloads: BTreeMap<String, Workload>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Loads and validates the manifest from an artifacts directory.
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        Self::parse(&text, dir)
    }

    /// Parses manifest JSON (exposed for tests).
    pub fn parse(text: &str, dir: PathBuf) -> anyhow::Result<Manifest> {
        let v = Json::parse(text)?;
        let strip = v
            .get("strip")
            .and_then(|s| s.as_f64())
            .ok_or_else(|| anyhow::anyhow!("manifest: missing strip"))?
            as usize;
        let wl = match v.get("workloads") {
            Some(Json::Obj(m)) => m,
            _ => anyhow::bail!("manifest: missing workloads object"),
        };
        let mut workloads = BTreeMap::new();
        for (name, w) in wl {
            let num = |k: &str| -> anyhow::Result<usize> {
                w.get(k)
                    .and_then(|x| x.as_f64())
                    .map(|x| x as usize)
                    .ok_or_else(|| anyhow::anyhow!("workload {name}: missing {k}"))
            };
            let file = w
                .get("file")
                .and_then(|f| f.as_str())
                .ok_or_else(|| anyhow::anyhow!("workload {name}: missing file"))?;
            workloads.insert(
                name.clone(),
                Workload {
                    name: name.clone(),
                    file: dir.join(file),
                    rows: num("rows")?,
                    cols: num("cols")?,
                    nnz: num("nnz")?,
                    iters: w.get("iters").and_then(|x| x.as_f64()).map(|x| x as usize),
                },
            );
        }
        Ok(Manifest { strip, workloads, dir })
    }

    /// Looks up a workload by name.
    pub fn workload(&self, name: &str) -> anyhow::Result<&Workload> {
        self.workloads
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("no workload '{name}' in manifest"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1, "strip": 256,
      "workloads": {
        "spmv": {"file": "s.hlo.txt", "rows": 16, "cols": 16, "nnz": 64},
        "cg": {"file": "c.hlo.txt", "rows": 16, "cols": 16, "nnz": 64, "iters": 10}
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/a")).unwrap();
        assert_eq!(m.strip, 256);
        let w = m.workload("cg").unwrap();
        assert_eq!(w.iters, Some(10));
        assert_eq!(w.file, PathBuf::from("/a/c.hlo.txt"));
        assert_eq!(m.workload("spmv").unwrap().nnz, 64);
        assert!(m.workload("nope").is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("{}", PathBuf::new()).is_err());
        assert!(Manifest::parse(
            r#"{"strip": 1, "workloads": {"w": {"file": "f"}}}"#,
            PathBuf::new()
        )
        .is_err());
        assert!(Manifest::parse("not json", PathBuf::new()).is_err());
    }

    #[test]
    fn real_manifest_if_built() {
        // When `make artifacts` has run, the real manifest must parse.
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        if std::path::Path::new(dir).join("manifest.json").exists() {
            let m = Manifest::load(dir).unwrap();
            assert!(m.workloads.contains_key("spmv"));
            assert!(m.workloads.contains_key("cg"));
        }
    }
}
