//! PJRT executor: compile HLO-text artifacts once, run them many times.
//!
//! Follows the reference wiring in /opt/xla-example/load_hlo: text →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`. Outputs are tuples
//! (`return_tuple=True` at lowering).
//!
//! The real PJRT path needs the `xla` crate (vendored separately) and
//! is compiled only with `--features xla`. Without the feature this
//! module exposes the **same API** as a stub whose constructors return
//! a descriptive error — so the engine, examples and tests build and
//! run everywhere, skipping the XLA path at runtime exactly like they
//! already skip it when no artifacts have been built.

use super::artifact::{Manifest, Workload};
#[cfg(feature = "xla")]
use std::collections::HashMap;
use std::path::Path;

#[cfg(feature = "xla")]
/// One compiled workload.
pub struct Executor {
    exe: xla::PjRtLoadedExecutable,
    pub workload: Workload,
}

#[cfg(feature = "xla")]
impl Executor {
    /// Runs the executable on f64 vector parameters, returning every
    /// tuple element flattened to `Vec<f64>`.
    pub fn run_f64(&self, params: &[&[f64]]) -> anyhow::Result<Vec<Vec<f64>>> {
        let literals: Vec<xla::Literal> =
            params.iter().map(|p| xla::Literal::vec1(p)).collect();
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        let parts = result.to_tuple()?;
        parts
            .into_iter()
            .map(|l| l.to_vec::<f64>().map_err(Into::into))
            .collect()
    }
}

#[cfg(feature = "xla")]
/// PJRT CPU client plus the compiled-executable cache.
pub struct XlaEngine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: HashMap<String, Executor>,
}

#[cfg(feature = "xla")]
impl XlaEngine {
    /// Creates the CPU client and loads the artifact manifest.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        let manifest = Manifest::load(&artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(XlaEngine { client, manifest, cache: HashMap::new() })
    }

    /// Platform string (for logs).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compiles (or returns the cached) executable for a workload.
    pub fn executor(&mut self, name: &str) -> anyhow::Result<&Executor> {
        if !self.cache.contains_key(name) {
            let w = self.manifest.workload(name)?.clone();
            let path = w
                .file
                .to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 artifact path"))?;
            let proto = xla::HloModuleProto::from_text_file(path)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.cache
                .insert(name.to_string(), Executor { exe, workload: w });
        }
        Ok(&self.cache[name])
    }
}

#[cfg(not(feature = "xla"))]
/// Stub executor (crate built without the `xla` feature) — never
/// constructed; [`XlaEngine::new`] fails first.
pub struct Executor {
    pub workload: Workload,
}

#[cfg(not(feature = "xla"))]
impl Executor {
    /// Always fails: no PJRT runtime is linked in.
    pub fn run_f64(&self, _params: &[&[f64]]) -> anyhow::Result<Vec<Vec<f64>>> {
        anyhow::bail!("spc5 was built without the `xla` feature")
    }
}

#[cfg(not(feature = "xla"))]
/// Stub engine (crate built without the `xla` feature): construction
/// reports the missing runtime, so callers fall back to the native
/// kernels the same way they do when artifacts are absent.
pub struct XlaEngine {
    pub manifest: Manifest,
}

#[cfg(not(feature = "xla"))]
impl XlaEngine {
    /// Always fails with a build-configuration message.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        let _ = Manifest::load(&artifacts_dir)?; // still validate the dir
        anyhow::bail!(
            "spc5 was built without the `xla` feature; rebuild with \
             `--features xla` (requires the vendored xla crate) to run \
             AOT artifacts"
        )
    }

    /// Platform string (for logs).
    pub fn platform(&self) -> String {
        "none (xla feature disabled)".to_string()
    }

    /// Always fails: no PJRT runtime is linked in.
    pub fn executor(&mut self, _name: &str) -> anyhow::Result<&Executor> {
        anyhow::bail!("spc5 was built without the `xla` feature")
    }
}

impl XlaEngine {
    /// Validates that a CSR matrix matches a workload's compiled
    /// shapes (rows/cols/nnz). Call before feeding `values`.
    pub fn validate_matrix(
        &self,
        name: &str,
        csr: &crate::matrix::Csr,
    ) -> anyhow::Result<()> {
        let w = self.manifest.workload(name)?;
        anyhow::ensure!(
            w.rows == csr.rows && w.cols == csr.cols && w.nnz == csr.nnz(),
            "matrix shape ({}, {}, nnz {}) does not match artifact '{name}' \
             ({}, {}, nnz {}) — regenerate artifacts or the matrix",
            csr.rows,
            csr.cols,
            csr.nnz(),
            w.rows,
            w.cols,
            w.nnz
        );
        Ok(())
    }
}

#[cfg(all(test, feature = "xla"))]
mod tests {
    use super::*;
    use crate::matrix::suite;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir =
            std::path::Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
        dir.join("manifest.json").exists().then(|| dir.to_path_buf())
    }

    /// End-to-end: the XLA artifact (jax+pallas lowered) must agree
    /// with the native Rust kernels on the shared Poisson workload.
    #[test]
    fn xla_spmv_matches_native() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let mut engine = XlaEngine::new(dir).unwrap();
        let w = engine.manifest.workload("spmv").unwrap().clone();
        let n = (w.rows as f64).sqrt() as usize;
        let csr = suite::poisson2d(n);
        engine.validate_matrix("spmv", &csr).unwrap();

        let x: Vec<f64> =
            (0..csr.cols).map(|i| ((i % 31) as f64) * 0.1 - 1.5).collect();
        let exe = engine.executor("spmv").unwrap();
        let out = exe.run_f64(&[&csr.values, &x]).unwrap();
        assert_eq!(out.len(), 1);

        let mut want = vec![0.0; csr.rows];
        csr.spmv_ref(&x, &mut want);
        for i in 0..csr.rows {
            assert!(
                (out[0][i] - want[i]).abs() <= 1e-9 * want[i].abs().max(1.0),
                "row {i}: xla {} vs native {}",
                out[0][i],
                want[i]
            );
        }
    }

    #[test]
    fn validate_matrix_rejects_mismatch() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let engine = XlaEngine::new(dir).unwrap();
        let wrong = suite::poisson2d(8);
        assert!(engine.validate_matrix("spmv", &wrong).is_err());
    }
}

#[cfg(all(test, not(feature = "xla")))]
mod stub_tests {
    use super::*;

    #[test]
    fn stub_reports_missing_feature() {
        let err = XlaEngine::new("definitely-missing-dir").unwrap_err();
        // Either the directory is missing or the feature is off; both
        // are descriptive errors, never a panic.
        assert!(!err.to_string().is_empty());
    }
}
