//! Shared drivers for the per-figure bench binaries.
//!
//! Each `cargo bench` target regenerates one paper table/figure; the
//! heavy lifting (suite iteration, measurement, record management)
//! lives here so the binaries stay declarative.

use super::{measure_parallel, measure_sequential, to_record, Measurement};
use crate::formats::stats::block_stats;
use crate::formats::{csr_to_block, BlockSize};
use crate::kernels::{KernelKind, KernelSet};
use crate::matrix::suite::SuiteMatrix;
use crate::parallel::{ParallelSpmv, ParallelStrategy};
use crate::predictor::{PerfRecord, RecordStore};

/// Honors `SPC5_QUICK=1`: trims a matrix list to a fast subset so the
/// full bench suite can be smoke-run in CI.
pub fn maybe_quick(mut ms: Vec<SuiteMatrix>) -> Vec<SuiteMatrix> {
    if std::env::var("SPC5_QUICK").ok().as_deref() == Some("1") {
        ms.truncate(6);
    }
    ms
}

/// `Avg(r,c)` feature for a kernel on a matrix (β(1,8) for baselines).
pub fn kernel_avg(k: KernelKind, csr: &crate::matrix::Csr) -> f64 {
    let bs = k.block_size().unwrap_or(BlockSize::new(1, 8));
    block_stats(csr, bs).avg_nnz_per_block
}

/// Measures all `kernels` sequentially on every matrix; returns the
/// measurements plus predictor records.
pub fn run_sequential(
    matrices: &[SuiteMatrix],
    kernels: &[KernelKind],
) -> (Vec<Measurement>, Vec<PerfRecord>) {
    let mut out = Vec::new();
    let mut recs = Vec::new();
    for sm in matrices {
        let set = KernelSet::prepare(sm.csr.clone(), kernels);
        for &k in kernels {
            let m = measure_sequential(&set, sm.name, k);
            recs.push(to_record(&m, kernel_avg(k, &sm.csr)));
            out.push(m);
        }
        eprintln!("  measured {}", sm.name);
    }
    (out, recs)
}

/// Measures β kernels in parallel on every matrix at each thread count
/// and NUMA mode.
pub fn run_parallel(
    matrices: &[SuiteMatrix],
    kernels: &[KernelKind],
    thread_counts: &[usize],
    numa_modes: &[bool],
) -> (Vec<Measurement>, Vec<PerfRecord>) {
    let mut out = Vec::new();
    let mut recs = Vec::new();
    for sm in matrices {
        for &k in kernels {
            let Some(bs) = k.block_size() else { continue };
            let bm = csr_to_block(&sm.csr, bs).expect("paper sizes valid");
            let avg = bm.avg_nnz_per_block();
            for &threads in thread_counts {
                for &numa in numa_modes {
                    let strategy = if numa {
                        ParallelStrategy::NumaSplit
                    } else {
                        ParallelStrategy::Shared
                    };
                    let p = ParallelSpmv::new(
                        bm.clone(),
                        threads,
                        strategy,
                        matches!(k, KernelKind::BetaTest(..)),
                    );
                    let m = measure_parallel(&p, sm.name, k);
                    // Records keep only the non-NUMA runs (one point per
                    // (kernel, matrix, threads), like the paper's fits).
                    if !numa {
                        recs.push(to_record(&m, avg));
                    }
                    out.push(m);
                }
            }
        }
        eprintln!("  measured {}", sm.name);
    }
    (out, recs)
}

/// Loads `records.json` when it already holds records at the wanted
/// thread counts; otherwise measures Set-A now and persists. Keeps the
/// prediction benches standalone while letting fig3/fig4 prime the
/// store.
pub fn ensure_records(
    matrices: &[SuiteMatrix],
    kernels: &[KernelKind],
    thread_counts: &[usize],
) -> anyhow::Result<RecordStore> {
    let path = super::records_path();
    // A corrupt store is quarantined by `load` — degrade to fresh
    // measurement instead of failing the bench run.
    let load_or_fresh = || match RecordStore::load(&path) {
        Ok(store) => store,
        Err(e) => {
            if !e.is_missing() {
                crate::util::durable::record_degrade(
                    crate::util::durable::DegradeEvent {
                        artifact: RecordStore::ARTIFACT.into(),
                        path: path.display().to_string(),
                        reason: e.to_string(),
                        fallback: "re-measure fresh store".into(),
                    },
                );
            }
            RecordStore::new()
        }
    };
    if path.exists() {
        let store = load_or_fresh();
        let have_all = thread_counts.iter().all(|&t| {
            kernels.iter().any(|&k| !store.for_kernel(k, t).is_empty())
        });
        if have_all {
            eprintln!("using existing records from {}", path.display());
            return Ok(store);
        }
    }
    eprintln!("priming record store (this measures Set-A once)...");
    let mut store = load_or_fresh();
    // Route through `push` so re-priming replaces stale measurements
    // instead of growing the store without bound.
    let mut merge = |recs: Vec<crate::predictor::PerfRecord>| {
        for r in recs {
            store.push(r);
        }
    };
    if thread_counts == [1] {
        let (_, recs) = run_sequential(matrices, kernels);
        merge(recs);
    } else {
        let seq_needed = thread_counts.contains(&1);
        if seq_needed {
            let (_, recs) = run_sequential(matrices, kernels);
            merge(recs);
        }
        let par: Vec<usize> =
            thread_counts.iter().copied().filter(|&t| t > 1).collect();
        if !par.is_empty() {
            let (_, recs) = run_parallel(matrices, kernels, &par, &[false]);
            merge(recs);
        }
    }
    store.save(&path)?;
    Ok(store)
}

/// Writes a machine-readable benchmark report (GFlop/s per matrix ×
/// kernel) — the artifact CI uploads so the perf trajectory of the
/// repo is tracked across commits (`BENCH_3.json` for the hybrid
/// ablation, `BENCH_4.json` for the tile-width ablation). Schema:
/// `{schema, suite, avx512, results: [{matrix, kernel, threads, numa,
/// tile, variant, gflops, seconds}]}` — `tile` is the column tile
/// width, `0` meaning flat (untiled) execution, so tiled-vs-flat
/// comparisons are machine-readable; `variant` is the kernel-variant
/// label (see [`crate::kernels::TuneParams::label`]), so per-variant
/// GFlop/s deltas (the `tune` ablation, `BENCH_7.json`) are too.
pub fn write_bench_json(
    path: &std::path::Path,
    suite_label: &str,
    measurements: &[Measurement],
) -> anyhow::Result<()> {
    use crate::util::json::Json;
    let results: Vec<Json> = measurements
        .iter()
        .map(|m| {
            Json::obj(vec![
                ("matrix", Json::Str(m.matrix.clone())),
                ("kernel", Json::Str(m.kernel.to_string())),
                ("threads", Json::Num(m.threads as f64)),
                ("numa", Json::Bool(m.numa)),
                ("tile", Json::Num(m.tile_cols as f64)),
                ("variant", Json::Str(m.tune.label())),
                ("gflops", Json::Num(m.gflops)),
                ("seconds", Json::Num(m.seconds)),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("schema", Json::Str("spc5-bench-v1".into())),
        ("suite", Json::Str(suite_label.into())),
        ("avx512", Json::Bool(crate::util::avx512_available())),
        ("results", Json::Arr(results)),
    ]);
    // Reports go through the same envelope + atomic-rename path as
    // every other persisted artifact (strip the header/footer lines,
    // or `read_bench_json`, to get the bare JSON back).
    crate::util::durable::save_state(
        "bench-report",
        path,
        &format!("{doc}\n"),
    )?;
    Ok(())
}

/// Reads a [`write_bench_json`] report back (envelope-verified; legacy
/// unwrapped reports load too) and returns the JSON text. A payload
/// that is not valid JSON — a corrupt legacy file, say — is
/// quarantined like every other artifact.
pub fn read_bench_json(path: &std::path::Path) -> anyhow::Result<String> {
    use crate::util::durable::{self, RawState, StateErrorKind};
    match durable::read_state("bench-report", path)? {
        RawState::Payload { text, .. } => {
            if let Err(e) = crate::util::json::Json::parse(&text) {
                return Err(durable::quarantined(
                    "bench-report",
                    path,
                    StateErrorKind::Malformed(e.to_string()),
                )
                .into());
            }
            Ok(text)
        }
        RawState::Missing => anyhow::bail!("{}: no such file", path.display()),
        RawState::Empty => anyhow::bail!("{}: file is empty", path.display()),
    }
}

/// Best measurement per matrix among `filter`-selected kernels.
pub fn best_by_matrix<'a>(
    ms: &'a [Measurement],
    filter: impl Fn(&Measurement) -> bool,
) -> std::collections::BTreeMap<String, &'a Measurement> {
    let mut best: std::collections::BTreeMap<String, &Measurement> =
        std::collections::BTreeMap::new();
    for m in ms.iter().filter(|m| filter(m)) {
        best.entry(m.matrix.clone())
            .and_modify(|b| {
                if m.gflops > b.gflops {
                    *b = m;
                }
            })
            .or_insert(m);
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::suite;

    #[test]
    fn run_sequential_counts() {
        let ms: Vec<SuiteMatrix> = suite::test_subset().into_iter().take(2).collect();
        let kernels = [KernelKind::Csr, KernelKind::Beta(1, 8)];
        let (out, recs) = run_sequential(&ms, &kernels);
        assert_eq!(out.len(), 4);
        assert_eq!(recs.len(), 4);
        assert!(out.iter().all(|m| m.gflops > 0.0));
    }

    #[test]
    fn best_by_matrix_picks_max() {
        let mk = |matrix: &str, g: f64| Measurement {
            matrix: matrix.into(),
            kernel: KernelKind::Csr,
            threads: 1,
            numa: false,
            tile_cols: 0,
            tune: Default::default(),
            gflops: g,
            seconds: 1.0,
        };
        let ms = vec![mk("a", 1.0), mk("a", 3.0), mk("b", 2.0)];
        let best = best_by_matrix(&ms, |_| true);
        assert_eq!(best["a"].gflops, 3.0);
        assert_eq!(best["b"].gflops, 2.0);
    }
}
