//! Reference values transcribed from the paper's tables, used by the
//! bench binaries to print "paper vs ours" columns.
//!
//! Values are `Avg(r,c) = N_NNZ / N_blocks(r,c)` for the six block
//! sizes in table order: β(1,8), β(2,4), β(2,8), β(4,4), β(4,8), β(8,4).

/// Paper Table 1 (Set-A): `(name, [avg per size])`.
pub const TABLE1_AVG: [(&str, [f64; 6]); 23] = [
    ("atmosmodd", [1.4, 2.8, 2.8, 4.7, 5.6, 5.1]),
    ("Ga19As19H42", [2.4, 3.7, 4.6, 6.6, 8.4, 7.7]),
    ("mip1", [6.5, 7.1, 13.0, 14.0, 25.0, 24.0]),
    ("rajat31", [1.4, 1.9, 1.9, 2.1, 2.3, 2.2]),
    ("bone010", [4.6, 5.9, 9.0, 11.0, 17.0, 16.0]),
    ("HV15R", [5.4, 5.7, 10.0, 9.7, 18.0, 15.0]),
    ("mixtank_new", [2.5, 3.0, 3.9, 3.8, 5.5, 4.9]),
    ("Si41Ge41H72", [2.6, 3.9, 5.0, 6.8, 9.0, 8.2]),
    ("cage15", [1.2, 2.0, 2.1, 3.1, 3.6, 3.4]),
    ("in-2004", [3.8, 4.4, 6.2, 6.7, 9.6, 9.6]),
    ("nd6k", [6.5, 6.6, 12.0, 12.0, 23.0, 22.0]),
    ("Si87H76", [1.8, 3.0, 3.4, 5.5, 6.5, 6.1]),
    ("circuit5M", [2.0, 3.3, 3.7, 5.5, 6.7, 6.7]),
    ("indochina-2004", [4.6, 5.1, 7.7, 8.3, 12.0, 13.0]),
    ("ns3Da", [1.2, 1.2, 1.3, 1.4, 1.5, 1.5]),
    ("CO", [1.5, 2.6, 2.9, 5.1, 5.7, 5.5]),
    ("kron_g500-logn21", [1.0, 1.0, 1.0, 1.0, 1.0, 1.0]),
    ("pdb1HYS", [6.2, 6.6, 12.0, 12.0, 20.0, 20.0]),
    ("torso1", [6.5, 7.5, 13.0, 13.0, 25.0, 21.0]),
    ("crankseg_2", [5.3, 6.0, 9.5, 9.7, 16.0, 15.0]),
    ("ldoor", [7.0, 6.4, 13.0, 11.0, 21.0, 17.0]),
    ("pwtk", [6.0, 6.7, 12.0, 13.0, 23.0, 21.0]),
    ("Dense-8000", [8.0, 8.0, 16.0, 16.0, 32.0, 32.0]),
];

/// Paper Table 2 (Set-B).
pub const TABLE2_AVG: [(&str, [f64; 6]); 11] = [
    ("bundle_adj", [5.8, 6.8, 11.0, 12.0, 21.0, 19.0]),
    ("Cube_Coup_dt0", [5.9, 8.0, 12.0, 16.0, 24.0, 20.0]),
    ("dielFilterV2real", [2.6, 2.6, 3.6, 3.6, 5.1, 4.9]),
    ("Emilia_923", [4.1, 5.0, 7.0, 7.5, 11.0, 11.0]),
    ("FullChip", [2.0, 2.4, 2.9, 3.3, 4.2, 4.2]),
    ("Hook_1498", [4.1, 5.1, 6.9, 7.7, 11.0, 11.0]),
    ("RM07R", [4.9, 4.7, 8.3, 7.6, 13.0, 12.0]),
    ("Serena", [4.1, 5.1, 7.0, 7.6, 11.0, 11.0]),
    ("spal_004", [6.0, 4.0, 7.3, 4.3, 8.1, 4.4]),
    ("TSOPF_RS_b2383_c1", [7.6, 7.8, 15.0, 15.0, 30.0, 29.0]),
    ("wikipedia-20060925", [1.1, 1.1, 1.1, 1.1, 1.1, 1.1]),
];

/// Paper reference avg for one matrix, if transcribed.
pub fn paper_avg(name: &str) -> Option<&'static [f64; 6]> {
    TABLE1_AVG
        .iter()
        .chain(TABLE2_AVG.iter())
        .find(|(n, _)| *n == name)
        .map(|(_, v)| v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_works() {
        assert_eq!(paper_avg("nd6k").unwrap()[0], 6.5);
        assert_eq!(paper_avg("wikipedia-20060925").unwrap()[5], 1.1);
        assert!(paper_avg("unknown").is_none());
    }

    #[test]
    fn tables_cover_suites() {
        // Every suite surrogate has a transcribed paper row.
        for sm in crate::matrix::suite::set_a() {
            assert!(paper_avg(sm.name).is_some(), "{}", sm.name);
        }
        for sm in crate::matrix::suite::set_b() {
            assert!(paper_avg(sm.name).is_some(), "{}", sm.name);
        }
    }
}
