//! Benchmark harness shared by the `cargo bench` targets.
//!
//! The vendor set has no `criterion`, so this module implements the
//! measurement protocol the paper itself uses: "The execution time is
//! measured as an average of 16 consecutive runs without accessing the
//! matrix before the first run", reported as GFlop/s = `2·nnz / T`.
//! Output is a markdown/CSV table per paper table/figure, printed to
//! stdout and optionally persisted for the predictor's record store.

pub mod paper_ref;
pub mod runner;

use crate::kernels::{KernelKind, KernelSet, TuneParams};
use crate::parallel::{ParallelSpmv, ParallelStrategy};
use crate::predictor::{PerfRecord, RecordStore};
use crate::scalar::Scalar;
use crate::util::timer::{mean_of_runs, spmv_gflops};
use crate::util::Rng;

/// Runs per measurement (the paper's protocol).
pub const RUNS: usize = 16;

/// One measured cell.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub matrix: String,
    pub kernel: KernelKind,
    pub threads: usize,
    pub numa: bool,
    /// Column tile width the run used (`0` = flat execution).
    pub tile_cols: usize,
    /// Kernel variant the run executed (baseline unless the producer
    /// swept variants — the `tune` ablation and `spc5 tune` do).
    pub tune: TuneParams,
    pub gflops: f64,
    pub seconds: f64,
}

/// Measures one kernel on a prepared [`KernelSet`] (sequential), at
/// either precision.
pub fn measure_sequential<T: Scalar>(
    set: &KernelSet<T>,
    matrix: &str,
    kernel: KernelKind,
) -> Measurement {
    let nnz = set.csr.nnz();
    let x: Vec<T> = bench_vector(set.csr.cols, 0xBE7C)
        .into_iter()
        .map(T::from_f64)
        .collect();
    let mut y = vec![T::ZERO; set.csr.rows];
    let seconds = mean_of_runs(RUNS, || {
        set.spmv(kernel, &x, &mut y);
    });
    std::hint::black_box(&y);
    Measurement {
        matrix: matrix.to_string(),
        kernel,
        threads: 1,
        numa: false,
        // The *resolved* width, so an auto-sized `tiled` run is not
        // mistaken for flat execution (`tile = 0`) in reports/records.
        tile_cols: set.tile_cols(kernel),
        tune: crate::kernels::default_tune(),
        gflops: spmv_gflops(nnz, seconds),
        seconds,
    }
}

/// Measures a β kernel on a pre-built parallel executor.
pub fn measure_parallel<T: Scalar>(
    p: &ParallelSpmv<T>,
    matrix: &str,
    kernel: KernelKind,
) -> Measurement {
    let bm = p.matrix();
    let nnz = bm.nnz();
    let x: Vec<T> = bench_vector(bm.cols, 0xBE7C)
        .into_iter()
        .map(T::from_f64)
        .collect();
    let mut y = vec![T::ZERO; bm.rows];
    let seconds = mean_of_runs(RUNS, || {
        p.spmv(&x, &mut y);
    });
    std::hint::black_box(&y);
    Measurement {
        matrix: matrix.to_string(),
        kernel,
        threads: p.n_threads(),
        numa: p.strategy() == ParallelStrategy::NumaSplit,
        tile_cols: kernel.tile_width(),
        tune: bm.tune,
        gflops: spmv_gflops(nnz, seconds),
        seconds,
    }
}

/// Measures the batched multi-RHS product (`k` right-hand sides in one
/// traversal) on a pre-built parallel executor. `gflops` counts the
/// work of all `k` vectors — the serving-throughput view.
pub fn measure_spmm<T: Scalar>(
    p: &ParallelSpmv<T>,
    matrix: &str,
    kernel: KernelKind,
    k: usize,
) -> Measurement {
    let bm = p.matrix();
    let nnz = bm.nnz();
    let x: Vec<T> = bench_vector(bm.cols * k, 0xBE7C)
        .into_iter()
        .map(T::from_f64)
        .collect();
    let mut y = vec![T::ZERO; bm.rows * k];
    let seconds = mean_of_runs(RUNS, || {
        p.spmm(&x, &mut y, k);
    });
    std::hint::black_box(&y);
    Measurement {
        matrix: matrix.to_string(),
        kernel,
        threads: p.n_threads(),
        numa: p.strategy() == ParallelStrategy::NumaSplit,
        tile_cols: kernel.tile_width(),
        tune: bm.tune,
        gflops: k as f64 * spmv_gflops(nnz, seconds),
        seconds,
    }
}

/// The deterministic input vector used by every benchmark.
pub fn bench_vector(len: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    (0..len).map(|_| rng.range_f64(-1.0, 1.0)).collect()
}

/// Converts measurements into predictor records (`avg` computed by the
/// caller, since it depends on the kernel's block size).
pub fn to_record(m: &Measurement, avg: f64) -> PerfRecord {
    PerfRecord {
        matrix: m.matrix.clone(),
        kernel: m.kernel,
        avg_nnz_per_block: avg,
        threads: m.threads,
        tile_cols: m.tile_cols,
        tune: m.tune,
        gflops: m.gflops,
    }
}

/// Markdown table writer for the bench binaries.
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells);
    }

    /// Renders the table as github-style markdown.
    pub fn to_markdown(&self) -> String {
        let mut out = format!("\n## {}\n\n", self.title);
        out.push_str(&format!("| {} |\n", self.header.join(" | ")));
        out.push_str(&format!(
            "|{}\n",
            "---|".repeat(self.header.len())
        ));
        for r in &self.rows {
            out.push_str(&format!("| {} |\n", r.join(" | ")));
        }
        out
    }

    /// Renders as CSV (for plotting scripts).
    pub fn to_csv(&self) -> String {
        let mut out = self.header.join(",");
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.join(","));
            out.push('\n');
        }
        out
    }

    /// Prints markdown to stdout and, when `SPC5_BENCH_OUT` is set,
    /// writes the CSV next to it for later analysis.
    pub fn emit(&self, slug: &str) {
        println!("{}", self.to_markdown());
        if let Ok(dir) = std::env::var("SPC5_BENCH_OUT") {
            let path = std::path::Path::new(&dir).join(format!("{slug}.csv"));
            if let Err(e) = std::fs::write(&path, self.to_csv()) {
                eprintln!("warning: could not write {}: {e}", path.display());
            }
        }
    }
}

/// Persist + merge records into the store file used by `spc5 predict`
/// and the prediction benches (default `records.json`, override with
/// `SPC5_RECORDS`).
pub fn records_path() -> std::path::PathBuf {
    std::env::var("SPC5_RECORDS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("records.json"))
}

/// Appends records to the store file (creating it if missing).
/// Re-measurements of a configuration replace the old record
/// ([`RecordStore::push`] dedupes), so repeated bench runs keep the
/// store bounded.
pub fn append_records(records: &[PerfRecord]) -> anyhow::Result<()> {
    let path = records_path();
    let mut store = if path.exists() {
        RecordStore::load(&path)?
    } else {
        RecordStore::new()
    };
    for r in records {
        store.push(r.clone());
    }
    store.save(&path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::suite;

    #[test]
    fn measure_sequential_produces_positive_gflops() {
        let csr = suite::poisson2d(24);
        let set = KernelSet::prepare(csr, &[KernelKind::Csr, KernelKind::Beta(1, 8)]);
        let m = measure_sequential(&set, "poisson", KernelKind::Beta(1, 8));
        assert!(m.gflops > 0.0);
        assert!(m.seconds > 0.0);
        assert_eq!(m.threads, 1);
    }

    #[test]
    fn measure_spmm_produces_positive_gflops() {
        let csr = suite::poisson2d(16);
        let bm = crate::formats::csr_to_block(
            &csr,
            crate::formats::BlockSize::new(2, 4),
        )
        .unwrap();
        let p = ParallelSpmv::new(bm, 2, ParallelStrategy::Shared, false);
        let m = measure_spmm(&p, "poisson", KernelKind::Beta(2, 4), 4);
        assert!(m.gflops > 0.0);
        assert_eq!(m.threads, 2);
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new("Fig X", &["matrix", "gflops"]);
        t.row(vec!["m1".into(), "1.23".into()]);
        let md = t.to_markdown();
        assert!(md.contains("## Fig X"));
        assert!(md.contains("| m1 | 1.23 |"));
        let csv = t.to_csv();
        assert!(csv.starts_with("matrix,gflops\n"));
        assert!(csv.contains("m1,1.23"));
    }

    #[test]
    fn bench_vector_deterministic() {
        assert_eq!(bench_vector(16, 1), bench_vector(16, 1));
        assert_ne!(bench_vector(16, 1), bench_vector(16, 2));
    }
}
