//! # SPC5-RS — block-based SpMV without zero padding
//!
//! Reproduction of Bramas & Kus, *"Computing the sparse matrix vector
//! product using block-based kernels without zero padding on processors
//! with AVX-512 instructions"* (PeerJ CS, 2018) — the SPC5 library —
//! grown into a precision-generic SpMV serving stack.
//!
//! ## The generic stack
//!
//! Every layer is parameterized over the sealed [`Scalar`] trait
//! (`f64` and `f32`, with `f64` as the default type parameter): one
//! `Csr<T>` → `BlockMatrix<T>` → kernel → engine pipeline instead of
//! per-precision copies. The scalar decides the lane count of a
//! 512-bit vector (8 doubles / 16 floats), the per-block-row mask word
//! (`u8` / `u16`) and the AVX-512 dispatch (`vexpandpd` /
//! `vexpandps`). Double-precision code looks exactly like it did when
//! the crate was f64-only; single precision is the same API at
//! `T = f32` with blocks up to 16 columns wide (`β32`).
//!
//! ```no_run
//! use spc5::{Csr, SpmvEngine, KernelKind};
//!
//! # fn demo(csr: Csr) -> anyhow::Result<()> {
//! // f64 (default): predictor-driven kernel choice, 4 worker threads.
//! let engine = SpmvEngine::builder(csr.clone()).threads(4).build()?;
//! let x = vec![1.0; csr.cols];
//! let mut y = vec![0.0; csr.rows];
//! engine.spmv_into(&x, &mut y);
//!
//! // f32: same stack, 16-lane blocks, explicit kernel override.
//! let _engine32 = SpmvEngine::builder(csr.to_precision::<f32>())
//!     .kernel(KernelKind::Beta(1, 16))
//!     .build()?;
//! # Ok(()) }
//! ```
//!
//! ## Plan architecture (inspector–executor)
//!
//! Engine construction is split into an **inspection** phase that
//! decides and a separate **instantiation** phase that converts — with
//! a first-class, serializable [`SpmvPlan`] between them (the same
//! split as MKL's inspector–executor API, the paper's comparison
//! target):
//!
//! ```text
//!             inspect                    serialize
//!   Csr ──► builder.plan() ──► SpmvPlan ──► JSON ──► (disk / wire)
//!             │  cheap Avg(r,c) scans        │
//!             │  predictor ranking           ▼
//!             │  hybrid panel schedule   SpmvPlan::from_json
//!             │  tile-width resolution       │
//!             ▼         instantiate          ▼
//!   builder.build() ═══ SpmvEngine::from_plan(csr, &plan)
//!                            │  fingerprint check, conversion only
//!                            ▼         execute
//!                        SpmvEngine ──► spmv / spmm
//! ```
//!
//! - [`SpmvEngineBuilder::plan`] records **every** decision — kernel
//!   kind with resolved block size, resolved column tile width, the
//!   compiled hybrid row-panel schedule (per-segment row range +
//!   kernel), reorder kind, threads, NUMA split, predicted GFlop/s —
//!   plus a [`MatrixFingerprint`] (dims, nnz, occupancy-stats hash).
//! - [`SpmvEngine::from_plan`] instantiates with **no selection**: the
//!   predictor, the record store and the fitted surfaces are not
//!   consulted. `build()` is exactly `plan()` + instantiation, so a
//!   plan round-tripped through JSON reproduces the built engine
//!   bit-for-bit; a plan applied to a matrix with a different
//!   fingerprint is refused.
//! - [`PlanCache`] persists `{fingerprint → plan}` as JSON
//!   ([`SpmvEngineBuilder::plan_cache`]): a server plans once per
//!   matrix shape and instantiates from cache on every repeat build —
//!   the "previous executions" of the paper's prediction system made
//!   executable. CLI: `spc5 plan --save plan.json` then
//!   `spc5 spmv --plan plan.json`.
//!
//! Every storage behind the engine implements the object-safe
//! [`formats::SparseStorage`] trait (`spmv_seq` / `spmv_pooled` /
//! `spmm` / `kernel_kind` / `validate`); a built engine holds exactly
//! one `Box<dyn SparseStorage<T>>` and dispatches products without
//! matching on the kernel kind.
//!
//! ## Solver stack (triangular solves & preconditioners)
//!
//! The Krylov drivers are preconditioned through one object-safe
//! trait, with the preconditioner's triangular kernels running on the
//! **same blocked storage and worker pool** as the SpMV they
//! accelerate:
//!
//! ```text
//!   Csr ──► triangular_split() ──► TriangularSplit { L, D, U }
//!             │                        │
//!             │          ┌─────────────┼──────────────┐
//!             │          ▼             ▼              ▼
//!             │     kernels::sptrsv  kernels::symgs  ILU(0) factor
//!             │     (CSR ref, masked (fwd/bwd/sym    (A's own
//!             │      β-block walk,    GS sweeps)      pattern)
//!             │      level-scheduled)
//!             │          └─────────────┬──────────────┘
//!             ▼                        ▼
//!   parallel::lower_levels /   Preconditioner<T>: z = M⁻¹·r
//!   upper_levels ──► levels    (IdentityPrecond | Jacobi | SymGs
//!   run per-level on the        | Ilu0, chosen via PrecondKind)
//!   engine's WorkerPool                │
//!                                      ▼
//!          cg_solve / pcg_with(engine, &M) / bicgstab
//!                                      │ persisted
//!                                      ▼
//!   SolvePlan { solver, precond, levels, SpmvPlan } ──► JSON
//!          solve_from_plan(): no inspection, no level re-analysis
//! ```
//!
//! - [`matrix::TriangularSplit`] partitions a square CSR matrix into
//!   strict-lower / diagonal / strict-upper once; SpTRSV, Gauss–Seidel
//!   and the ILU(0) factorization all run over the split.
//! - [`kernels::sptrsv`] solves `(D+L) x = b` / `(D+U) x = b` three
//!   ways — CSR reference, masked **β-block** substitution reusing the
//!   paper's interleaved header stream, and level-scheduled on the
//!   pool — all three **bit-identical** (each row accumulates in
//!   ascending column order in every execution).
//! - [`parallel::lower_levels`] / [`parallel::upper_levels`] build the
//!   dependency level sets; [`parallel::LevelSchedule`] decides
//!   sequential vs parallel (`parallel_worthwhile`) and its
//!   [`parallel::LevelSummary`] verdict is **persisted** in the
//!   [`coordinator::SolvePlan`], so a repeat solve skips the analysis.
//! - [`coordinator::Preconditioner`] implementations: `none`,
//!   `jacobi` (typed [`coordinator::PrecondError::ZeroDiagonal`]
//!   instead of the old silent identity substitution — only the
//!   deprecated [`coordinator::pcg_jacobi`] shim keeps the lenient
//!   behavior), `symgs(n)`, `ilu0`. [`coordinator::pcg_with`] runs
//!   PCG with any of them; [`coordinator::CgReport::breakdown`]
//!   distinguishes numerical breakdowns from max-iteration exits.
//! - CLI: `spc5 solve --matrix poisson2d-large --precond symgs
//!   --solver pcg --save-plan solve.json`, then `--plan solve.json`
//!   to replay the executor half.
//!
//! ## Runtime architecture
//!
//! Every parallel path runs on **one persistent
//! [`parallel::WorkerPool`]** rather than per-call thread spawning:
//!
//! - **Pool lifecycle** — a parallel engine spawns its pool once at
//!   `build()` and owns it for its lifetime. The β runtime
//!   (`ParallelSpmv`), the row-chunked CSR baseline, every iteration
//!   of the Krylov solvers, and the serving layer all hand work to the
//!   same parked workers. A standalone `ParallelSpmv::new` creates its
//!   own pool; `ParallelSpmv::with_pool` attaches to a shared one.
//! - **Epoch handoff** — `pool.run(task)` publishes a borrowed closure,
//!   bumps an epoch counter and wakes the workers; each worker computes
//!   its span into its own reusable working vector and merges into its
//!   disjoint slice of `y` as soon as *it* finishes (the paper's
//!   syncless merge: "it does not wait for the others"); the caller
//!   returns when the last worker checks in. No spawn, no channel, no
//!   allocation per call.
//! - **NUMA first-touch** — in `NumaSplit` modes each worker *itself*
//!   materializes its private copy of its sub-arrays (values, headers,
//!   rowptr) inside its `LocalStore` at attach time, so on a
//!   multi-socket host the copies land on the worker's local memory
//!   node by first touch — previously the copies were made once on the
//!   constructing thread while workers changed every call.
//! - **Batched serving** — `SpmvService` runs a micro-batching
//!   dispatcher: concurrent requests against the same matrix coalesce
//!   into one multi-RHS `SpmvEngine::spmm` call (the block kernels
//!   traverse the matrix once for all `k` right-hand sides), falling
//!   back to single-vector SpMV for a batch of one.
//!
//! ## Serving architecture
//!
//! The serving tier scales the dispatcher out and puts every queue
//! under admission control
//! ([`coordinator::cluster`] / [`coordinator::serving`] /
//! [`coordinator::tenant`]):
//!
//! ```text
//!                         submit(x)          recv() → y = y₀ ‖ y₁ ‖ y₂
//!                            │                        ▲
//!                   ┌────────▼─────────┐     ┌────────┴────────┐
//!                   │  AdmissionGate   │     │     fan-in      │
//!                   │ capacity + policy│     │ concat y slices │
//!                   └────────┬─────────┘     └────────▲────────┘
//!              fan-out: x to every shard              │
//!         ┌──────────────────┼──────────────────┐     │
//!         ▼                  ▼                  ▼     │
//!   ┌───────────┐      ┌───────────┐      ┌───────────┐
//!   │  shard 0  │      │  shard 1  │      │  shard 2  │  SpmvService
//!   │ rows 0..a │      │ rows a..b │      │ rows b..n │  + SpmvEngine
//!   │ pool+NUMA │      │ pool+NUMA │      │ pool+NUMA │  per shard
//!   └───────────┘      └───────────┘      └───────────┘
//! ```
//!
//! - **Shard cut** — [`coordinator::ShardedService`] splits the rows
//!   with [`parallel::balanced_row_ranges`]: nnz-balanced over the CSR
//!   row pointer and aligned to the 8-row β interval, so each shard's
//!   block structure is exactly the full matrix's restricted to its
//!   rows and the sharded product is **bit-identical** to the
//!   single-engine one. Each shard owns an engine (own kernel storage,
//!   own `WorkerPool`, optional first-touch NUMA arrays) and a
//!   dispatcher.
//! - **Admission policies** — every queue is bounded
//!   ([`coordinator::QueuePolicy`]): `Block { capacity }` applies
//!   backpressure, `Reject { capacity }` sheds load with
//!   `ServiceError::Overloaded`, `Timeout { capacity, wait }` waits up
//!   to a deadline. A slot is held from `submit` until the client
//!   `recv`s the response, so `capacity` bounds total resident
//!   request/response memory. The sharded front-end admits **once**
//!   per request at its gate; shard queues then provably never fill.
//! - **Latency accounting** — responses and stats split latency into
//!   queue vs compute components with separate p50/p95/p99 sets, plus
//!   rejection counts and the queue-depth high-water mark.
//! - **Tenant registry** — [`coordinator::TenantRegistry`] keys
//!   running services by [`MatrixFingerprint`] to host many matrices
//!   in one process. Registration cold-starts through the shared
//!   [`PlanCache`] (`plan → from_plan`, no re-inspection when any
//!   earlier tenant planned the same structure) or directly from a
//!   saved [`SpmvPlan`]; per-tenant and registry-wide stats expose
//!   served/rejected counts and the cold-start cost. CLI:
//!   `spc5 serve --matrix fem-large --shards 4 --queue reject
//!   --capacity 64`.
//!
//! ## Panel scheduling (the hybrid kernel)
//!
//! The predictor picks *one* kernel per matrix, but real matrices are
//! heterogeneous within themselves. [`KernelKind::Hybrid`]
//! (`formats::HybridMatrix`) cuts the rows into fixed-height panels
//! (a multiple of 8 rows, `SpmvEngine::builder(..).panel_rows(..)`)
//! and decides per panel: candidate β sizes below the paper's Eq.-4
//! storage crossover are discarded, survivors and CSR are ranked on
//! the predictor's fitted GFlop/s surface (when records are supplied)
//! or on the analytic bandwidth model. A schedule compiler merges
//! adjacent same-choice panels and converts each merged run **once**,
//! so the hot loop is a flat walk over precompiled `(kernel, span)`
//! segments — β segments on the AVX-512 span kernels, CSR segments on
//! the tuned row loop — with zero per-panel branching. The parallel
//! path splits the segment list by nnz (`balanced_prefix_split`) and
//! runs the chunks on the engine's `WorkerPool`; `spmm` batches all
//! right-hand sides through the same schedule.
//!
//! A related lever ships alongside:
//! `SpmvEngine::builder(..).reorder(..)` applies RCM or
//! column-packing at build time — the engine stores the permuted
//! matrix and transparently permutes `x`/`y` on every product, so
//! callers keep their original index space while conversion sees the
//! improved block fill.
//!
//! ## Autotuning (machine-level kernel variants)
//!
//! The β hot loops are compiled as a small table of monomorphized
//! **variants** ([`kernels::VARIANT_TABLE`]) differing in header/value
//! prefetch distances, `x`-prefetch and 2× block unrolling
//! ([`kernels::TuneParams`]) — knobs whose best setting depends on the
//! executing machine. The variant is resolved **once per storage** and
//! dispatched per kernel span; the block loops themselves contain no
//! per-block branching and no global atomic reads:
//!
//! ```text
//!   spc5 tune ──► sweep: every variant × β kernel   (offline, 16-run
//!        │               on representative matrices    paper protocol)
//!        ├──► RecordStore      records carry the variant
//!        ▼
//!   TuneProfile JSON (machine-keyed per-kernel winners)
//!        │  builder.tune_profile(path)      builder.tune(params)
//!        ▼                                  (explicit override)
//!   plan(): SpmvPlan.tune + per-segment ScheduleEntry.tune
//!        ▼
//!   from_plan(): BlockMatrix.tune → dispatch_variant! → Var<V> loop
//! ```
//!
//! - **Sweep** — `spc5 tune [--quick]` ([`tuner::sweep`]) benchmarks
//!   every variant on structurally distinct generators (or a user
//!   matrix), persists per-measurement [`predictor::PerfRecord`]s
//!   (keyed on the variant, so tuned and baseline records coexist)
//!   and writes the machine-keyed [`tuner::TuneProfile`].
//! - **Plan** — `SpmvEngine::builder(..).tune_profile(path)` consults
//!   the profile at inspection: the planned kernel gets its winner,
//!   and each β segment of a hybrid schedule gets the winner swept for
//!   *its* block size. The choice is pinned into the serializable
//!   [`SpmvPlan`], so a tuned plan replayed by
//!   [`SpmvEngine::from_plan`] reproduces the build bit-for-bit with
//!   no profile file present.
//! - **Dispatch** — instantiation stamps the variant into the storage
//!   (`BlockMatrix::tune`); every span call dispatches the
//!   monomorphized variant once per segment. Variants only reorder
//!   *when* streams are prefetched, never the FMA order, so every
//!   variant is bit-identical to the baseline (differential tests pin
//!   this down across precisions, runtimes and kernel classes).
//!
//! The process-wide default ([`kernels::default_tune`]) honors the
//! `SPC5_NO_PREFETCH` ablation variable; the old
//! [`kernels::avx512::set_prefetch`] toggle survives as a deprecated
//! shim mapping onto it.
//!
//! ## Cache blocking (column tiling)
//!
//! The β kernels stream their own arrays perfectly, but every block
//! load of `x` is indexed by block column: once `x` outgrows the
//! last-level cache (wide matrices, scattered columns), those loads
//! dominate. [`formats::TiledMatrix`] / [`formats::TiledHybrid`]
//! reorder a converted storage into a **(row-panel × column-tile)**
//! schedule: blocks are bucketed by the tile containing their anchor
//! column, each `(panel, tile)` group is a self-contained kernel span
//! whose `colidx` are tile-relative, and execution walks panels
//! outermost, tiles innermost. One tile pass touches only a
//! `tile_cols`-sized `x` window (cache-resident for the whole pass);
//! the panel's `y` rows stay hot across all of its tiles. The spans
//! run through the *existing* masked kernels unchanged — only the `x`
//! slice starts at the tile base ([`kernels::avx512::spmv_span_at`]) —
//! for SpMV and the multi-RHS SpMM alike.
//!
//! Spelling: `SpmvEngine::builder(..).tile_cols(n)` / `.tile_auto()`
//! tiles a β or hybrid engine; [`KernelKind::Tiled`] (`parse` accepts
//! `tiled` and `tiled(n)`) names the tiled hybrid schedule directly.
//! Auto sizing reads the per-core L2 (override with `SPC5_L2_BYTES`)
//! and budgets half of it for the `x` window
//! ([`formats::auto_tile_cols`]). Parallel execution is a 2-D
//! schedule on the engine's `WorkerPool`: workers own disjoint,
//! nnz-balanced **row-panel** ranges (no atomics on `y`), tiles stay
//! an inner sequential loop for locality.
//!
//! Prefer tiling when `x` is much larger than the LLC share and the
//! columns touched per row spread widely (`matrix::suite::wide_random`
//! is the stress generator); skip it for narrow or strongly banded
//! matrices, where the window is cache-resident anyway and the extra
//! per-span dispatch only costs. Numerically, a tiled product equals
//! the flat one up to summation order: each row's contributions are
//! accumulated per tile and then added, so results may differ from the
//! flat kernel in the last bits (exactly bit-identical when one tile
//! covers all columns); the differential tests pin this down.
//!
//! ## Fault tolerance
//!
//! The serving tier is supervised: a shard dispatcher that dies (a
//! kernel task panicking mid-batch) is *restarted*, not silently
//! lost. The machinery rests on the serializable [`SpmvPlan`] — the
//! [`ShardedService`] retains each shard's sub-matrix and plan at
//! start, so recovery is a bit-reproducible
//! [`SpmvEngine::from_plan`] rebuild, never a re-inspection that
//! could pick a different kernel.
//!
//! ```text
//!   submit ──▶ gate ──▶ fan-out (generation g stamped)
//!                          │
//!               shard k dispatcher panics
//!                          │
//!                 recv ◀── FailGuard: failed=true, queue closed
//!                          │
//!              supervisor (first receiver to notice):
//!                1. drain live shards of generation g
//!                2. charge restart budget  ──exhausted──▶ poison all
//!                3. rebuild shard k via from_plan (generation g+1)
//!                4. fail generation g: Err(ShardFailed { shard: k,
//!                   generation: g }) to its blocked receivers
//!                          │
//!              subsequent submits serve normally at g+1
//! ```
//!
//! Failure is **typed** end to end: `submit` refuses with
//! [`coordinator::ServiceError::ShardFailed`], receivers blocked in
//! `recv` wake with [`coordinator::RecvError::Failed`] (clean
//! shutdown stays [`coordinator::RecvError::Stopped`] — the two are
//! never conflated), and per-shard [`coordinator::HealthReport`]s
//! (Up / Restarting / Poisoned, restart count, last fault) are
//! surfaced through `ShardedService::health`, the tenant registry,
//! and `spc5 serve`. A sliding-window restart budget
//! ([`coordinator::RestartBudget`], default 8 restarts / 60 s) is the
//! circuit breaker: recovery that keeps failing escalates to the old
//! poison-everything behavior instead of thrashing. The tenant layer
//! adds [`coordinator::TenantRegistry::submit_with_retry`] — bounded
//! retries with linear backoff that ride through a restart window.
//!
//! All of it is tested against **deterministic fault injection**
//! ([`faults`]): a seeded [`faults::FaultPlan`] of rules fires
//! panics and delays at named sites. The always-compiled check at
//! each site is one relaxed atomic load when no plan is installed,
//! so the fault-free hot path is unaffected (the `chaos` ablation in
//! `kernel_micro` pins the overhead; `BENCH_8.json`).
//!
//! | site      | where it fires                      | actions       |
//! |-----------|-------------------------------------|---------------|
//! | `compute` | shard dispatcher, per batch         | panic, delay  |
//! | `submit`  | service admission, per request      | delay         |
//! | `recv`    | client receive path, per response   | delay         |
//! | `worker`  | pool worker, inside `catch_unwind`  | panic, delay  |
//!
//! Plans come from the environment (`SPC5_FAULTS`, seed in
//! `SPC5_FAULTS_SEED`) or [`faults::install_global`]. The grammar is
//! `ACTION@SITE:key=value,...` joined by `;` — e.g.
//! `panic@compute:shard=1,nth=3` (kill shard 1's third batch) or
//! `delay@recv:ms=2,every=7` (2 ms stall on every 7th receive);
//! selectors `shard=`, `request=`, `nth=`, `every=`, `prob=`,
//! `times=` compose, and `prob` draws from the plan seed so a
//! schedule replays identically. `spc5 serve --chaos` runs the demo
//! loop under a canned plan as a self-healing smoke test. The
//! durable-state layer adds the `io_write` / `io_read` sites and the
//! `torn{at}` action (`torn@io_write:at=24,nth=0` tears the first
//! state write after 24 bytes) — the substrate of the
//! crash-consistency suite.
//!
//! ## Durability & input hardening
//!
//! Every JSON artifact the stack persists — [`PlanCache`],
//! [`predictor::RecordStore`], [`TuneProfile`], a saved [`SpmvPlan`],
//! and the `BENCH_*.json` reports — goes through one durable state
//! layer ([`util::durable`]) instead of bare `fs::write`/`fs::read`:
//!
//! - **Atomic writes** — [`util::AtomicFile`] writes a temp sibling,
//!   fsyncs it, and renames it over the destination (fsyncing the
//!   parent directory best-effort), so a crash mid-save leaves either
//!   the old state or the new state, never a torn file.
//! - **Checksummed envelope** — payloads are framed as
//!   `SPC5STATEv1 <len>\n` + payload + `\nSPC5SUM <fnv1a-64, 16 hex>\n`.
//!   Loads verify the version, the declared length, and the checksum;
//!   a file *without* the magic prefix is accepted as trusted-legacy
//!   (pre-envelope artifacts keep loading unchanged).
//! - **Quarantine ladder** — a file that fails verification or JSON
//!   parsing is renamed to `<name>.corrupt-<n>` (evidence preserved,
//!   path freed for repair) and surfaces as a typed
//!   [`util::StateError`] naming the artifact, the path, the failure
//!   kind ([`util::durable::StateErrorKind`]: I/O, wrong version, bad
//!   envelope, truncation, checksum mismatch, malformed payload) and
//!   the quarantine location.
//! - **Graceful degradation** — corruption is an event, not a crash.
//!   Each caller maps the error to its safe fallback and records a
//!   [`util::DegradeEvent`] on the process-wide log (surfaced through
//!   [`TenantRegistry`] stats and printed by `spc5 serve` / `spc5
//!   tune`):
//!
//! | artifact          | missing            | empty / whitespace  | corrupt                           |
//! |-------------------|--------------------|---------------------|-----------------------------------|
//! | plan cache        | fresh cache        | warn + fresh cache  | quarantine, re-plan, persist anew |
//! | record store      | error (named file) | warn + fresh store  | quarantine, fresh / analytic model|
//! | tune profile      | error (named file) | quarantine + error  | quarantine, baseline tune params  |
//! | saved plan        | error              | error               | quarantine + error                |
//! | bench report      | error              | error               | quarantine + error                |
//!
//! Untrusted *input* is hardened separately: the MatrixMarket reader
//! ([`matrix::market`]) is a bounded-memory streaming parser — one
//! reusable line buffer capped at [`matrix::market::MAX_LINE`] bytes,
//! preallocation from header claims capped, overflow-checked index
//! arithmetic, non-finite value rejection — and every malformed input
//! fails with a line-numbered `MatrixError::Market` (the CLI exits
//! nonzero with `<file>: line <n>: <reason>`), never a panic. The
//! corruption-differential suite (`tests/durability.rs`) flips every
//! byte of every artifact and proves detection + quarantine + a
//! bit-identical cold start; the mutation corpus
//! (`tests/market_mutations.rs`) does the same for the parser. The
//! `durable` ablation in `kernel_micro` pins the envelope overhead
//! against raw I/O (`BENCH_9.json`).
//!
//! ## Modules
//!
//! - [`scalar`] — the sealed [`Scalar`] / [`scalar::MaskWord`] traits:
//!   the precision axis everything else is generic over.
//! - [`matrix`] — sparse-matrix substrate: `Coo<T>` / `Csr<T>`
//!   containers, MatrixMarket I/O, a dense oracle, reordering, and
//!   deterministic synthetic generators reproducing the structural
//!   classes of the paper's SuiteSparse benchmark sets.
//! - [`formats`] — the paper's contribution: `β(r,c)` block formats
//!   storing one *bitmask per block* instead of zero padding
//!   (`BlockMatrix<T>`), conversion from CSR, block statistics, the
//!   memory-occupancy model (paper Eq. 1–4), the heterogeneous
//!   row-panel schedule (`HybridMatrix<T>`: per-panel β/CSR choice
//!   compiled into flat kernel segments), and the cache-blocked
//!   column-tiled layouts (`TiledMatrix<T>` / `TiledHybrid<T>`).
//! - [`kernels`] — SpMV kernels behind one dispatch: the generic
//!   scalar Algorithm 1/2, native AVX-512 `vexpandpd` (f64) and
//!   `vexpandps` (f32) span kernels, a tuned CSR baseline (MKL
//!   stand-in) and a CSR5 re-implementation — all runnable through
//!   `KernelSet<T>` / [`kernels::spmv_block`].
//! - [`parallel`] — the persistent worker-pool runtime
//!   (`WorkerPool`) plus the paper's static block-balanced
//!   shared-memory parallelization with per-thread result buffers,
//!   syncless merge and an optional NUMA-style array split
//!   (`ParallelSpmv<T>`, multi-RHS `spmm` included).
//! - [`predictor`] — the record-based kernel-selection system:
//!   polynomial interpolation (sequential, Fig. 5) and 2D regression
//!   (parallel, Fig. 6) over performance records.
//! - [`tuner`] — the machine-level kernel autotuner: offline sweep of
//!   the β kernel-variant table, machine-keyed `TuneProfile`
//!   persistence, and the plan-time lookup the engine consults.
//! - [`runtime`] — PJRT/XLA executor loading AOT artifacts produced by
//!   the Python (JAX + Pallas) compile path (behind the `xla` feature;
//!   a stub with the same API otherwise).
//! - [`coordinator`] — `SpmvEngine<T>` (built through
//!   [`SpmvEngine::builder`]: stats → predict → convert → dispatch,
//!   serving **every** [`KernelKind`] including the CSR/CSR5
//!   baselines, owning one pool for all its parallel paths), the
//!   Krylov solvers with their plan-aware preconditioners (each
//!   iteration reuses the engine's pool; `SolvePlan` persists the
//!   whole solve configuration), and the
//!   serving tier: micro-batching `SpmvService<T>`, bounded admission
//!   queues, the sharded, supervised `ShardedService<T>` front-end
//!   and the multi-tenant `TenantRegistry<T>`.
//! - [`faults`] — deterministic fault injection: seeded
//!   [`faults::FaultPlan`] rules fired at named sites
//!   (`SPC5_FAULTS`), the substrate of the chaos test suite.
//! - [`bench`] — the measurement harness used by `cargo bench` targets
//!   that regenerate every table and figure of the paper.

pub mod bench;
pub mod coordinator;
pub mod faults;
pub mod formats;
pub mod kernels;
pub mod matrix;
pub mod parallel;
pub mod predictor;
pub mod runtime;
pub mod scalar;
pub mod testkit;
pub mod tuner;
pub mod util;

/// Number of f64 lanes in a 512-bit vector — the paper's `VEC_SIZE`.
/// The generic form is [`Scalar::LANES`] (8 for f64, 16 for f32).
pub const VEC_SIZE: usize = 8;

pub use coordinator::{
    solve_from_plan, CgReport, HealthReport, Ilu0, Jacobi, MatrixFingerprint,
    PlanCache, PrecondError, PrecondKind, Preconditioner, QueuePolicy,
    RecvError, RestartBudget, ShardConfig, ShardHealth, ShardedService,
    SolvePlan, SolverKind, SpmvEngine, SpmvEngineBuilder, SpmvPlan,
    SpmvService, SymGs, TenantConfig, TenantRegistry,
};
pub use formats::{BlockMatrix, BlockSize, SparseStorage};
pub use kernels::{default_tune, KernelKind, TuneParams, VARIANT_TABLE};
pub use matrix::{Coo, Csr, TriangularSplit};
pub use parallel::{LevelSchedule, LevelSummary};
pub use scalar::Scalar;
pub use tuner::TuneProfile;
pub use util::{AtomicFile, DegradeEvent, StateError};
