//! # SPC5-RS — block-based SpMV without zero padding
//!
//! Reproduction of Bramas & Kus, *"Computing the sparse matrix vector
//! product using block-based kernels without zero padding on processors
//! with AVX-512 instructions"* (PeerJ CS, 2018) — the SPC5 library —
//! as a three-layer Rust + JAX + Pallas system.
//!
//! The crate provides:
//!
//! - [`matrix`] — sparse-matrix substrate: COO / CSR containers,
//!   MatrixMarket I/O, a dense oracle, and deterministic synthetic
//!   generators reproducing the structural classes of the paper's
//!   SuiteSparse benchmark sets (Set-A / Set-B).
//! - [`formats`] — the paper's contribution: `β(r,c)` block formats that
//!   store one *bitmask per block* instead of zero padding, conversion
//!   from CSR, block statistics and the memory-occupancy model
//!   (paper Eq. 1–4).
//! - [`kernels`] — SpMV kernels: the generic scalar Algorithm 1, native
//!   AVX-512 `vexpandpd` kernels for the six paper block sizes, the
//!   Algorithm 2 "test" variants, a tuned CSR baseline (MKL stand-in)
//!   and a full CSR5 re-implementation (Liu & Vinter 2015).
//! - [`parallel`] — the paper's static block-balanced shared-memory
//!   parallelization with per-thread result buffers, syncless merge and
//!   an optional NUMA-style array split.
//! - [`predictor`] — the record-based kernel-selection system:
//!   polynomial interpolation (sequential, Fig. 5) and 2D regression
//!   (parallel, Fig. 6) over performance records.
//! - [`runtime`] — PJRT/XLA executor loading AOT artifacts produced by
//!   the Python (JAX + Pallas) compile path.
//! - [`coordinator`] — the `SpmvEngine` facade tying everything
//!   together (stats → predict → convert → dispatch) plus a CG solver.
//! - [`bench`] — the measurement harness used by `cargo bench` targets
//!   that regenerate every table and figure of the paper.

pub mod bench;
pub mod coordinator;
pub mod formats;
pub mod kernels;
pub mod matrix;
pub mod parallel;
pub mod predictor;
pub mod runtime;
pub mod testkit;
pub mod util;

/// Number of f64 lanes in a 512-bit vector — the paper's `VEC_SIZE`.
pub const VEC_SIZE: usize = 8;

pub use formats::{BlockMatrix, BlockSize};
pub use kernels::KernelKind;
pub use matrix::{Coo, Csr};
