//! # SPC5-RS — block-based SpMV without zero padding
//!
//! Reproduction of Bramas & Kus, *"Computing the sparse matrix vector
//! product using block-based kernels without zero padding on processors
//! with AVX-512 instructions"* (PeerJ CS, 2018) — the SPC5 library —
//! grown into a precision-generic SpMV serving stack.
//!
//! ## The generic stack
//!
//! Every layer is parameterized over the sealed [`Scalar`] trait
//! (`f64` and `f32`, with `f64` as the default type parameter): one
//! `Csr<T>` → `BlockMatrix<T>` → kernel → engine pipeline instead of
//! per-precision copies. The scalar decides the lane count of a
//! 512-bit vector (8 doubles / 16 floats), the per-block-row mask word
//! (`u8` / `u16`) and the AVX-512 dispatch (`vexpandpd` /
//! `vexpandps`). Double-precision code looks exactly like it did when
//! the crate was f64-only; single precision is the same API at
//! `T = f32` with blocks up to 16 columns wide (`β32`).
//!
//! ```no_run
//! use spc5::{Csr, SpmvEngine, KernelKind};
//!
//! # fn demo(csr: Csr) -> anyhow::Result<()> {
//! // f64 (default): predictor-driven kernel choice, 4 worker threads.
//! let engine = SpmvEngine::builder(csr.clone()).threads(4).build()?;
//! let x = vec![1.0; csr.cols];
//! let mut y = vec![0.0; csr.rows];
//! engine.spmv_into(&x, &mut y);
//!
//! // f32: same stack, 16-lane blocks, explicit kernel override.
//! let _engine32 = SpmvEngine::builder(csr.to_precision::<f32>())
//!     .kernel(KernelKind::Beta(1, 16))
//!     .build()?;
//! # Ok(()) }
//! ```
//!
//! ## Modules
//!
//! - [`scalar`] — the sealed [`Scalar`] / [`scalar::MaskWord`] traits:
//!   the precision axis everything else is generic over.
//! - [`matrix`] — sparse-matrix substrate: `Coo<T>` / `Csr<T>`
//!   containers, MatrixMarket I/O, a dense oracle, reordering, and
//!   deterministic synthetic generators reproducing the structural
//!   classes of the paper's SuiteSparse benchmark sets.
//! - [`formats`] — the paper's contribution: `β(r,c)` block formats
//!   storing one *bitmask per block* instead of zero padding
//!   (`BlockMatrix<T>`), conversion from CSR, block statistics and the
//!   memory-occupancy model (paper Eq. 1–4).
//! - [`kernels`] — SpMV kernels behind one dispatch: the generic
//!   scalar Algorithm 1/2, native AVX-512 `vexpandpd` (f64) and
//!   `vexpandps` (f32) span kernels, a tuned CSR baseline (MKL
//!   stand-in) and a CSR5 re-implementation — all runnable through
//!   `KernelSet<T>` / [`kernels::spmv_block`].
//! - [`parallel`] — the paper's static block-balanced shared-memory
//!   parallelization with per-thread result buffers, syncless merge
//!   and an optional NUMA-style array split (`ParallelSpmv<T>`).
//! - [`predictor`] — the record-based kernel-selection system:
//!   polynomial interpolation (sequential, Fig. 5) and 2D regression
//!   (parallel, Fig. 6) over performance records.
//! - [`runtime`] — PJRT/XLA executor loading AOT artifacts produced by
//!   the Python (JAX + Pallas) compile path (behind the `xla` feature;
//!   a stub with the same API otherwise).
//! - [`coordinator`] — `SpmvEngine<T>` (built through
//!   [`SpmvEngine::builder`]: stats → predict → convert → dispatch,
//!   serving **every** [`KernelKind`] including the CSR/CSR5
//!   baselines), the Krylov solvers, and `SpmvService<T>`.
//! - [`bench`] — the measurement harness used by `cargo bench` targets
//!   that regenerate every table and figure of the paper.

pub mod bench;
pub mod coordinator;
pub mod formats;
pub mod kernels;
pub mod matrix;
pub mod parallel;
pub mod predictor;
pub mod runtime;
pub mod scalar;
pub mod testkit;
pub mod util;

/// Number of f64 lanes in a 512-bit vector — the paper's `VEC_SIZE`.
/// The generic form is [`Scalar::LANES`] (8 for f64, 16 for f32).
pub const VEC_SIZE: usize = 8;

pub use coordinator::SpmvEngine;
pub use formats::{BlockMatrix, BlockSize};
pub use kernels::KernelKind;
pub use matrix::{Coo, Csr};
pub use scalar::Scalar;
