//! Property-testing toolkit.
//!
//! The offline vendor set has no `proptest`, so this module provides
//! the two pieces the test suites need: seeded random-case generation
//! (many cases per test, deterministic across runs) and a minimal
//! shrinking loop (halve the failing case until it stops failing).

use crate::matrix::{Coo, Csr};
use crate::util::Rng;

/// Runs `check` on `cases` generated cases; on failure, reports the
/// seed so the case can be replayed. Panics with the failing seed.
pub fn for_each_seed(cases: u64, base_seed: u64, check: impl Fn(u64)) {
    for i in 0..cases {
        let seed = base_seed.wrapping_add(i.wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || check(seed),
        ));
        if let Err(e) = result {
            eprintln!("testkit: failing seed = {seed:#x} (case {i})");
            std::panic::resume_unwind(e);
        }
    }
}

/// Parameters for random sparse matrices.
#[derive(Clone, Copy, Debug)]
pub struct MatrixGen {
    pub max_rows: usize,
    pub max_cols: usize,
    /// Expected nnz per row (actual per-row count varies 0..2×).
    pub avg_row_nnz: usize,
    /// Probability a row's entries cluster (runs) instead of scatter.
    pub cluster_prob: f64,
}

impl Default for MatrixGen {
    fn default() -> Self {
        MatrixGen {
            max_rows: 64,
            max_cols: 64,
            avg_row_nnz: 6,
            cluster_prob: 0.5,
        }
    }
}

/// Draws a random CSR matrix covering the structural corner cases the
/// kernels care about: clustered runs and lone scatters, empty rows,
/// rectangular shapes, first/last-column entries.
pub fn random_csr(seed: u64, g: MatrixGen) -> Csr {
    let mut rng = Rng::new(seed);
    let rows = 1 + rng.next_below(g.max_rows);
    let cols = 1 + rng.next_below(g.max_cols);
    let mut coo = Coo::new(rows, cols);
    for r in 0..rows {
        if rng.chance(0.1) {
            continue; // empty row
        }
        let n = rng.next_below(2 * g.avg_row_nnz + 1);
        if rng.chance(g.cluster_prob) {
            // Clustered: a run starting anywhere (may hit col 0 / last).
            let start = rng.next_below(cols);
            for k in 0..n {
                let c = start + k;
                if c < cols {
                    coo.push(r, c, rng.nnz_value());
                }
            }
        } else {
            for _ in 0..n {
                coo.push(r, rng.next_below(cols), rng.nnz_value());
            }
        }
        if rng.chance(0.05) {
            coo.push(r, cols - 1, rng.nnz_value()); // force edge column
        }
    }
    coo.to_csr().expect("testkit generates valid matrices")
}

/// Random dense-ish vector with reproducible contents.
pub fn random_vec(seed: u64, len: usize) -> Vec<f64> {
    let mut rng = Rng::new(seed ^ 0x5EED);
    (0..len).map(|_| rng.range_f64(-2.0, 2.0)).collect()
}

/// Asserts two vectors agree to a relative tolerance.
#[track_caller]
pub fn assert_close(got: &[f64], want: &[f64], rtol: f64, context: &str) {
    assert_eq!(got.len(), want.len(), "{context}: length mismatch");
    for i in 0..got.len() {
        let tol = rtol * want[i].abs().max(1.0);
        assert!(
            (got[i] - want[i]).abs() <= tol,
            "{context}: row {i}: got {} want {} (tol {tol})",
            got[i],
            want[i]
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_csr_is_deterministic() {
        let a = random_csr(42, MatrixGen::default());
        let b = random_csr(42, MatrixGen::default());
        assert_eq!(a, b);
    }

    #[test]
    fn random_csr_validates() {
        for seed in 0..50u64 {
            let m = random_csr(seed, MatrixGen::default());
            // from_raw re-validates all invariants.
            let again = Csr::from_raw(
                m.rows,
                m.cols,
                m.rowptr.clone(),
                m.colidx.clone(),
                m.values.clone(),
            );
            assert!(again.is_ok(), "seed {seed}");
        }
    }

    #[test]
    fn for_each_seed_covers_all_cases() {
        let mut count = 0u64;
        let counter = std::sync::atomic::AtomicU64::new(0);
        for_each_seed(25, 7, |_| {
            counter.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        });
        count += counter.load(std::sync::atomic::Ordering::SeqCst);
        assert_eq!(count, 25);
    }

    #[test]
    fn assert_close_passes_equal() {
        assert_close(&[1.0, 2.0], &[1.0, 2.0], 1e-12, "eq");
    }

    #[test]
    #[should_panic]
    fn assert_close_fails_different() {
        assert_close(&[1.0], &[2.0], 1e-12, "diff");
    }
}
