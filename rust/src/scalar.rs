//! The sealed [`Scalar`] trait — the precision axis of the crate.
//!
//! The paper notes AVX-512 holds "16 single precision or eight double
//! precision floating point values"; everything downstream of that one
//! sentence is captured here. A [`Scalar`] bundles:
//!
//! - the element type (`f64` or `f32`),
//! - its per-block-row **mask word** ([`Scalar::Mask`]): `u8` rows ×
//!   8 lanes for `f64`, `u16` rows × 16 lanes for `f32`,
//! - the AVX-512 span dispatch hook ([`Scalar::spmv_span_simd`]) that
//!   routes a `β(r,c)` span to the `vexpandpd` / `vexpandps` kernels.
//!
//! `Csr<T>`, `BlockMatrix<T>`, `KernelSet<T>`, `SpmvEngine<T>` and
//! `SpmvService<T>` are all generic over this trait, with `T = f64` as
//! the default type parameter so double precision code reads exactly
//! like it did before the API became generic.
//!
//! The trait is **sealed**: the format invariants, the unsafe kernels
//! and the header layout are only proven for these two instantiations.

use crate::formats::BlockSize;
use crate::kernels::avx512::{self, Span, TuneParams};

mod private {
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for f64 {}
    impl Sealed for u8 {}
    impl Sealed for u16 {}
}

/// A per-block-row bitmask word (`u8` for β, `u16` for β32).
///
/// Bit `k` set ⇔ the block row holds a value at column `col0 + k`.
pub trait MaskWord:
    private::Sealed
    + Copy
    + PartialEq
    + Eq
    + std::hash::Hash
    + std::fmt::Debug
    + Send
    + Sync
    + 'static
{
    /// Lanes addressable by this mask (8 or 16).
    const BITS: usize;
    /// Bytes one mask occupies in the interleaved header stream.
    const BYTES: usize;
    /// The empty mask.
    const ZERO: Self;

    /// A mask with only bit `k` set.
    fn bit(k: usize) -> Self;
    /// Sets bit `k` in place.
    fn set(&mut self, k: usize);
    /// Whether bit `k` is set.
    fn test(self, k: usize) -> bool;
    /// Number of set bits.
    fn count_ones(self) -> u32;
    /// Index of the lowest set bit (`BITS` when empty).
    fn trailing_zeros(self) -> u32;
    /// The mask with the low `c` bits set (`c <= BITS`).
    fn low_bits(c: usize) -> Self;
    /// Whether any bit at position `>= c` is set.
    fn any_above(self, c: usize) -> bool;
    /// Whether no bit is set.
    fn is_zero(self) -> bool;
    /// Appends the little-endian byte encoding to a header stream.
    fn push_le(self, out: &mut Vec<u8>);
    /// Reads a mask from the first `BYTES` bytes of a header slice.
    fn read_le(bytes: &[u8]) -> Self;
}

impl MaskWord for u8 {
    const BITS: usize = 8;
    const BYTES: usize = 1;
    const ZERO: u8 = 0;

    #[inline]
    fn bit(k: usize) -> u8 {
        1u8 << k
    }
    #[inline]
    fn set(&mut self, k: usize) {
        *self |= 1u8 << k;
    }
    #[inline]
    fn test(self, k: usize) -> bool {
        self & (1u8 << k) != 0
    }
    #[inline]
    fn count_ones(self) -> u32 {
        u8::count_ones(self)
    }
    #[inline]
    fn trailing_zeros(self) -> u32 {
        u8::trailing_zeros(self)
    }
    #[inline]
    fn low_bits(c: usize) -> u8 {
        if c >= 8 {
            0xFF
        } else {
            (1u8 << c) - 1
        }
    }
    #[inline]
    fn any_above(self, c: usize) -> bool {
        self & !Self::low_bits(c) != 0
    }
    #[inline]
    fn is_zero(self) -> bool {
        self == 0
    }
    #[inline]
    fn push_le(self, out: &mut Vec<u8>) {
        out.push(self);
    }
    #[inline]
    fn read_le(bytes: &[u8]) -> u8 {
        bytes[0]
    }
}

impl MaskWord for u16 {
    const BITS: usize = 16;
    const BYTES: usize = 2;
    const ZERO: u16 = 0;

    #[inline]
    fn bit(k: usize) -> u16 {
        1u16 << k
    }
    #[inline]
    fn set(&mut self, k: usize) {
        *self |= 1u16 << k;
    }
    #[inline]
    fn test(self, k: usize) -> bool {
        self & (1u16 << k) != 0
    }
    #[inline]
    fn count_ones(self) -> u32 {
        u16::count_ones(self)
    }
    #[inline]
    fn trailing_zeros(self) -> u32 {
        u16::trailing_zeros(self)
    }
    #[inline]
    fn low_bits(c: usize) -> u16 {
        if c >= 16 {
            0xFFFF
        } else {
            (1u16 << c) - 1
        }
    }
    #[inline]
    fn any_above(self, c: usize) -> bool {
        self & !Self::low_bits(c) != 0
    }
    #[inline]
    fn is_zero(self) -> bool {
        self == 0
    }
    #[inline]
    fn push_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    #[inline]
    fn read_le(bytes: &[u8]) -> u16 {
        u16::from_le_bytes([bytes[0], bytes[1]])
    }
}

/// A floating-point element type the SPC5 stack is instantiated at.
pub trait Scalar:
    private::Sealed
    + Copy
    + Default
    + PartialEq
    + PartialOrd
    + std::fmt::Debug
    + std::fmt::Display
    + std::fmt::LowerExp
    + std::ops::Add<Output = Self>
    + std::ops::Sub<Output = Self>
    + std::ops::Mul<Output = Self>
    + std::ops::Div<Output = Self>
    + std::ops::Neg<Output = Self>
    + std::ops::AddAssign
    + std::ops::SubAssign
    + std::ops::MulAssign
    + Send
    + Sync
    + 'static
{
    /// Per-block-row mask word (`u8` for f64, `u16` for f32).
    type Mask: MaskWord;

    /// Additive identity.
    const ZERO: Self;
    /// Multiplicative identity.
    const ONE: Self;
    /// Lanes in a 512-bit vector (8 for f64, 16 for f32).
    const LANES: usize;
    /// Bytes per element.
    const BYTES: usize;
    /// Human-readable name ("f64" / "f32").
    const NAME: &'static str;

    /// Lossy conversion from double precision.
    fn from_f64(v: f64) -> Self;
    /// Widening conversion to double precision.
    fn to_f64(self) -> f64;
    /// Absolute value.
    fn abs(self) -> Self;
    /// Whether the value is neither infinite nor NaN.
    fn is_finite(self) -> bool;

    /// Runs one `β(r,c)` span through this scalar's AVX-512 kernels,
    /// at the resolved [`TuneParams`] kernel variant. Returns `false`
    /// when no specialization exists for `bs` (or the host lacks
    /// AVX-512); the caller falls back to the portable Algorithm-1
    /// kernel.
    fn spmv_span_simd(
        span: Span<'_, Self>,
        bs: BlockSize,
        x: &[Self],
        y: &mut [Self],
        test: bool,
        tune: TuneParams,
    ) -> bool;

    /// Runs one span of the multi-RHS product (`k` right-hand sides,
    /// row-major `X`/`Y` — see [`crate::kernels::spmm`]) through this
    /// scalar's SIMD specialization, if one exists for `k`, at the
    /// resolved [`TuneParams`] variant. Returns `false` to fall back
    /// to the portable span SpMM.
    fn spmm_span_simd(
        span: Span<'_, Self>,
        bs: BlockSize,
        x: &[Self],
        y: &mut [Self],
        k: usize,
        tune: TuneParams,
    ) -> bool;
}

impl Scalar for f64 {
    type Mask = u8;

    const ZERO: f64 = 0.0;
    const ONE: f64 = 1.0;
    const LANES: usize = 8;
    const BYTES: usize = 8;
    const NAME: &'static str = "f64";

    #[inline]
    fn from_f64(v: f64) -> f64 {
        v
    }
    #[inline]
    fn to_f64(self) -> f64 {
        self
    }
    #[inline]
    fn abs(self) -> f64 {
        f64::abs(self)
    }
    #[inline]
    fn is_finite(self) -> bool {
        f64::is_finite(self)
    }

    #[inline]
    fn spmv_span_simd(
        span: Span<'_, f64>,
        bs: BlockSize,
        x: &[f64],
        y: &mut [f64],
        test: bool,
        tune: TuneParams,
    ) -> bool {
        avx512::spmv_span_f64(span, bs, x, y, test, tune)
    }

    #[inline]
    fn spmm_span_simd(
        span: Span<'_, f64>,
        bs: BlockSize,
        x: &[f64],
        y: &mut [f64],
        k: usize,
        tune: TuneParams,
    ) -> bool {
        crate::kernels::spmm::spmm_span_simd_f64(span, bs, x, y, k, tune)
    }
}

impl Scalar for f32 {
    type Mask = u16;

    const ZERO: f32 = 0.0;
    const ONE: f32 = 1.0;
    const LANES: usize = 16;
    const BYTES: usize = 4;
    const NAME: &'static str = "f32";

    #[inline]
    fn from_f64(v: f64) -> f32 {
        v as f32
    }
    #[inline]
    fn to_f64(self) -> f64 {
        self as f64
    }
    #[inline]
    fn abs(self) -> f32 {
        f32::abs(self)
    }
    #[inline]
    fn is_finite(self) -> bool {
        f32::is_finite(self)
    }

    #[inline]
    fn spmv_span_simd(
        span: Span<'_, f32>,
        bs: BlockSize,
        x: &[f32],
        y: &mut [f32],
        test: bool,
        tune: TuneParams,
    ) -> bool {
        avx512::spmv_span_f32(span, bs, x, y, test, tune)
    }

    #[inline]
    fn spmm_span_simd(
        _span: Span<'_, f32>,
        _bs: BlockSize,
        _x: &[f32],
        _y: &mut [f32],
        _k: usize,
        _tune: TuneParams,
    ) -> bool {
        // No f32 SpMM specialization yet; the generic span kernel
        // still gives the one-traversal multi-RHS batching win.
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mask_word_bit_ops() {
        assert_eq!(<u8 as MaskWord>::low_bits(8), 0xFF);
        assert_eq!(<u8 as MaskWord>::low_bits(3), 0b111);
        assert_eq!(<u16 as MaskWord>::low_bits(16), 0xFFFF);
        assert_eq!(<u16 as MaskWord>::low_bits(9), 0x1FF);
        let mut m = <u16 as MaskWord>::ZERO;
        m.set(0);
        m.set(15);
        assert!(m.test(0) && m.test(15) && !m.test(7));
        assert_eq!(MaskWord::count_ones(m), 2);
        assert_eq!(MaskWord::trailing_zeros(m), 0);
        assert!(m.any_above(15));
        assert!(!m.any_above(16));
    }

    #[test]
    fn mask_word_le_roundtrip() {
        let mut buf = Vec::new();
        0xABu8.push_le(&mut buf);
        0xBEEFu16.push_le(&mut buf);
        assert_eq!(buf, vec![0xAB, 0xEF, 0xBE]);
        assert_eq!(<u8 as MaskWord>::read_le(&buf[0..]), 0xAB);
        assert_eq!(<u16 as MaskWord>::read_le(&buf[1..]), 0xBEEF);
    }

    #[test]
    fn scalar_constants_line_up() {
        // One 512-bit vector = LANES elements = 64 bytes, and the mask
        // addresses exactly LANES lanes.
        assert_eq!(f64::LANES * f64::BYTES, 64);
        assert_eq!(f32::LANES * f32::BYTES, 64);
        assert_eq!(<<f64 as Scalar>::Mask as MaskWord>::BITS, f64::LANES);
        assert_eq!(<<f32 as Scalar>::Mask as MaskWord>::BITS, f32::LANES);
    }

    #[test]
    fn precision_conversions() {
        assert_eq!(f32::from_f64(1.5), 1.5f32);
        assert_eq!(Scalar::to_f64(2.5f32), 2.5f64);
        assert!(Scalar::is_finite(1.0f64));
        assert!(!Scalar::is_finite(f32::NAN));
    }
}
