//! Static block-balanced partitioning (paper §Parallelization).
//!
//! "Our objective is to have approximately the same number of blocks
//! per thread … without distributing one row to multiple threads. We
//! add the next r rows if
//! `|(tid+1)·N_b/t − N_blocks[row]| < |(tid+1)·N_b/t − N_blocks[row+1]|`."

use crate::formats::BlockMatrix;
use crate::scalar::{MaskWord, Scalar};

/// The row-interval span assigned to one thread.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ThreadSpan {
    /// First row interval (inclusive).
    pub interval_begin: usize,
    /// One past the last row interval.
    pub interval_end: usize,
    /// First matrix row covered.
    pub row_begin: usize,
    /// One past the last matrix row covered (clamped to `rows`).
    pub row_end: usize,
    /// First block index.
    pub block_begin: usize,
    /// One past the last block index.
    pub block_end: usize,
    /// First value index (prefix popcount).
    pub val_begin: usize,
}

/// Splits the positions `0..prefix.len()-1` into `n` contiguous
/// chunks whose prefix-sum weights are approximately equal, using the
/// paper's absolute-difference test: a chunk keeps growing while doing
/// so brings its cumulative weight closer to `(tid+1)·total/n`.
///
/// `prefix` is any monotone prefix-sum array (`prefix[i]` = weight
/// before item `i`): block counts per row interval here, nnz per row
/// for the engine's parallel-CSR path.
pub fn balanced_prefix_split(prefix: &[u32], n: usize) -> Vec<(usize, usize)> {
    assert!(n > 0);
    assert!(!prefix.is_empty());
    let items = prefix.len() - 1;
    let per = prefix[items] as f64 / n as f64;
    let mut chunks = Vec::with_capacity(n);
    let mut i = 0usize;
    for tid in 0..n {
        let begin = i;
        if tid == n - 1 {
            i = items;
        } else {
            let target = (tid + 1) as f64 * per;
            while i < items {
                let here = prefix[i] as f64;
                let next = prefix[i + 1] as f64;
                if (target - here).abs() < (target - next).abs() {
                    break;
                }
                i += 1;
            }
        }
        chunks.push((begin, i));
    }
    chunks
}

/// Cuts the rows of an nnz prefix (`rowptr`) into at most `n`
/// contiguous, non-empty, nnz-balanced row ranges — the serving
/// tier's shard cut. Interior boundaries are rounded to the nearest
/// multiple of `align` (pass 1 for the raw prefix split): aligning to
/// the 8-row β interval makes each shard's block conversion reproduce
/// exactly the full matrix's blocks restricted to the shard's rows —
/// blocks are formed jointly across an interval's rows, so an
/// unaligned cut would re-partition the boundary blocks and change
/// the in-block reduction order. Alignment is what lets a sharded
/// product be bit-identical to the unsharded one.
///
/// Empty ranges (more shards than rows, rounding collisions) are
/// dropped, so fewer than `n` ranges can come back; the returned
/// ranges always cover `0..rows` contiguously, and at least one range
/// is returned whenever `rows > 0`.
pub fn balanced_row_ranges(
    rowptr: &[u32],
    n: usize,
    align: usize,
) -> Vec<(usize, usize)> {
    assert!(align > 0, "alignment must be >= 1");
    let rows = rowptr.len().saturating_sub(1);
    let raw = balanced_prefix_split(rowptr, n);
    let mut cuts: Vec<usize> = Vec::with_capacity(raw.len() + 1);
    cuts.push(0);
    for span in raw.iter().skip(1) {
        let rounded = ((span.0 + align / 2) / align * align).min(rows);
        let prev = *cuts.last().expect("cuts starts non-empty");
        cuts.push(rounded.max(prev));
    }
    cuts.push(rows);
    let mut ranges = Vec::with_capacity(cuts.len() - 1);
    for w in cuts.windows(2) {
        if w[1] > w[0] {
            ranges.push((w[0], w[1]));
        }
    }
    ranges
}

/// Splits the matrix's row intervals into `n_threads` spans using the
/// paper's balancing rule. Every interval is assigned to exactly one
/// thread; spans are contiguous and ordered; empty spans are possible
/// for degenerate matrices (fewer blocks than threads).
pub fn partition_intervals<T: Scalar>(
    bm: &BlockMatrix<T>,
    n_threads: usize,
) -> Vec<ThreadSpan> {
    let n_blocks = bm.n_blocks();

    // Prefix popcounts per block → value offsets for each span start.
    let r = bm.bs.r;
    let mut val_prefix = Vec::with_capacity(n_blocks + 1);
    val_prefix.push(0usize);
    let mut acc = 0usize;
    for b in 0..n_blocks {
        for i in 0..r {
            acc += bm.block_masks[b * r + i].count_ones() as usize;
        }
        val_prefix.push(acc);
    }

    balanced_prefix_split(&bm.block_rowptr, n_threads)
        .into_iter()
        .map(|(begin, it)| {
            let block_begin = bm.block_rowptr[begin] as usize;
            let block_end = bm.block_rowptr[it] as usize;
            ThreadSpan {
                interval_begin: begin,
                interval_end: it,
                row_begin: (begin * r).min(bm.rows),
                row_end: (it * r).min(bm.rows),
                block_begin,
                block_end,
                val_begin: val_prefix[block_begin],
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::{csr_to_block, BlockSize};
    use crate::matrix::suite;

    fn spans_for(n: usize, threads: usize) -> (Vec<ThreadSpan>, usize, usize) {
        let csr = suite::poisson2d(n);
        let bm = csr_to_block(&csr, BlockSize::new(2, 4)).unwrap();
        let spans = partition_intervals(&bm, threads);
        (spans, bm.intervals(), bm.n_blocks())
    }

    #[test]
    fn covers_all_intervals_disjointly() {
        for threads in [1usize, 2, 3, 4, 7, 16] {
            let (spans, intervals, n_blocks) = spans_for(30, threads);
            assert_eq!(spans.len(), threads);
            assert_eq!(spans[0].interval_begin, 0);
            assert_eq!(spans.last().unwrap().interval_end, intervals);
            assert_eq!(spans.last().unwrap().block_end, n_blocks);
            for w in spans.windows(2) {
                assert_eq!(w[0].interval_end, w[1].interval_begin);
                assert_eq!(w[0].block_end, w[1].block_begin);
                assert_eq!(w[0].row_end, w[1].row_begin);
            }
        }
    }

    #[test]
    fn balanced_within_one_interval_of_ideal() {
        let (spans, _, n_blocks) = spans_for(60, 4);
        let ideal = n_blocks as f64 / 4.0;
        for s in &spans {
            let got = (s.block_end - s.block_begin) as f64;
            // The balance is limited by interval granularity; Poisson
            // intervals hold ~2 rows × ~3 blocks, so tolerance is loose
            // but meaningful.
            assert!(
                (got - ideal).abs() <= ideal * 0.25 + 8.0,
                "span {s:?} far from ideal {ideal}"
            );
        }
    }

    #[test]
    fn more_threads_than_blocks() {
        let (spans, intervals, _) = spans_for(4, 32);
        assert_eq!(spans.len(), 32);
        assert_eq!(spans.last().unwrap().interval_end, intervals);
        // All intervals covered, some spans empty — still consistent.
        for w in spans.windows(2) {
            assert_eq!(w[0].interval_end, w[1].interval_begin);
        }
    }

    #[test]
    fn val_begin_matches_prefix() {
        let csr = suite::fem_blocked(200, 3, 5, 3);
        let bm = csr_to_block(&csr, BlockSize::new(4, 8)).unwrap();
        let spans = partition_intervals(&bm, 5);
        // val_begin of each span must equal the popcount of all masks
        // before its first block.
        for s in &spans {
            let mut pop = 0usize;
            for b in 0..s.block_begin {
                for i in 0..bm.bs.r {
                    pop += bm.block_masks[b * bm.bs.r + i].count_ones() as usize;
                }
            }
            assert_eq!(s.val_begin, pop);
        }
        assert_eq!(
            spans.last().unwrap().block_end,
            bm.n_blocks(),
            "last span must end at the last block"
        );
    }

    #[test]
    fn row_ranges_cover_rows_contiguously_and_aligned() {
        let csr = suite::fem_blocked(500, 3, 5, 3);
        for n in [1usize, 2, 3, 4, 8] {
            let ranges = balanced_row_ranges(&csr.rowptr, n, 8);
            assert!(!ranges.is_empty());
            assert_eq!(ranges[0].0, 0);
            assert_eq!(ranges.last().unwrap().1, csr.rows);
            for w in ranges.windows(2) {
                assert_eq!(w[0].1, w[1].0, "ranges must be contiguous");
            }
            for &(r0, r1) in &ranges {
                assert!(r1 > r0, "no empty ranges");
                // Every interior boundary sits on an 8-row interval.
                if r1 != csr.rows {
                    assert_eq!(r1 % 8, 0, "unaligned cut at {r1}");
                }
            }
        }
    }

    #[test]
    fn row_ranges_balance_nnz() {
        let csr = suite::fem_blocked(1_000, 3, 6, 4);
        let ranges = balanced_row_ranges(&csr.rowptr, 4, 8);
        assert_eq!(ranges.len(), 4);
        let ideal = csr.nnz() as f64 / 4.0;
        for &(r0, r1) in &ranges {
            let nnz = (csr.rowptr[r1] - csr.rowptr[r0]) as f64;
            // Rounding to 8-row boundaries costs at most a few rows'
            // worth of nonzeros per cut.
            assert!(
                (nnz - ideal).abs() <= ideal * 0.25 + 8.0 * 16.0,
                "shard [{r0},{r1}) nnz {nnz} far from ideal {ideal}"
            );
        }
    }

    #[test]
    fn row_ranges_more_shards_than_rows() {
        let csr = suite::poisson2d(3); // 9 rows
        let ranges = balanced_row_ranges(&csr.rowptr, 16, 8);
        assert!(!ranges.is_empty());
        assert!(ranges.len() <= 16);
        assert_eq!(ranges[0].0, 0);
        assert_eq!(ranges.last().unwrap().1, csr.rows);
        for w in ranges.windows(2) {
            assert_eq!(w[0].1, w[1].0);
        }
    }

    #[test]
    fn single_thread_gets_everything() {
        let (spans, intervals, n_blocks) = spans_for(20, 1);
        assert_eq!(spans[0].interval_begin, 0);
        assert_eq!(spans[0].interval_end, intervals);
        assert_eq!(spans[0].block_end - spans[0].block_begin, n_blocks);
        assert_eq!(spans[0].val_begin, 0);
    }
}
