//! Shared-memory parallel SpMV — the paper's §Parallelization, on a
//! persistent runtime.
//!
//! - [`pool`] — the long-lived [`pool::WorkerPool`]: parked worker
//!   threads woken by epoch handoff, each owning reusable per-worker
//!   scratch ([`pool::LocalStore`]). Created once, shared by every
//!   layer above (β runtime, engine CSR chunks, solvers, service).
//! - [`partition`] — the static block-balanced row-interval split: each
//!   thread receives whole row intervals with approximately
//!   `N_blocks / N_threads` blocks, decided by the paper's
//!   absolute-difference test.
//! - [`exec`] — the executor façade: per-thread working vectors for
//!   `y`, merge without synchronization (the assigned row spans are
//!   disjoint), an optional NUMA-style mode where every thread copies
//!   its sub-matrix arrays **on its own thread** (first-touch
//!   placement), and a multi-RHS [`exec::ParallelSpmv::spmm`] path.
//! - [`levels`] — level scheduling for the triangular-solve kernels:
//!   dependency level sets built from strict-triangular structure,
//!   executed level-by-level on the same pool (with a sequential
//!   fallback when the levels are too shallow to pay for the epochs).
//!
//! No per-call thread spawning anywhere: `ParallelSpmv::new` spawns the
//! workers once (or attaches to an existing pool via `with_pool`), and
//! every subsequent product is a wake → compute → syncless-merge epoch.

pub mod exec;
pub mod levels;
pub mod partition;
pub mod pool;

pub use exec::{ParallelSpmv, ParallelStrategy};
pub use levels::{
    lower_levels, run_levels, upper_levels, LevelSchedule, LevelSummary,
};
pub use partition::{
    balanced_prefix_split, balanced_row_ranges, partition_intervals,
    ThreadSpan,
};
pub use pool::{LocalStore, SendSlice, WorkerCtx, WorkerPool};
