//! Shared-memory parallel SpMV — the paper's §Parallelization.
//!
//! - [`partition`] — the static block-balanced row-interval split: each
//!   thread receives whole row intervals with approximately
//!   `N_blocks / N_threads` blocks, decided by the paper's
//!   absolute-difference test.
//! - [`exec`] — the worker pool: per-thread working vectors for `y`,
//!   merge without synchronization (the assigned row spans are
//!   disjoint), and an optional NUMA-style mode where every thread owns
//!   a private copy of its sub-matrix arrays (on a multi-socket host
//!   these copies land on the local node by first touch; the code
//!   structure is identical here, the single-socket container just
//!   cannot show the latency gap).

pub mod exec;
pub mod partition;

pub use exec::{ParallelSpmv, ParallelStrategy};
pub use partition::{balanced_prefix_split, partition_intervals, ThreadSpan};
