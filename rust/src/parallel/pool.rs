//! The persistent worker-pool runtime underneath every parallel path.
//!
//! The paper's parallel design assumes **long-lived** workers: each
//! thread is bound to its span of the matrix once, owns its working
//! vector, and on a NUMA host owns first-touch-placed copies of its
//! sub-arrays. Spawning fresh threads per SpMV (as the old
//! `std::thread::scope` runtime did) breaks all three properties — a
//! 500-iteration CG solve paid 500× thread creation and allocation,
//! and the "local" copies were touched once by the constructing thread
//! while the workers changed every call.
//!
//! [`WorkerPool`] fixes the lifecycle: `n` threads are spawned once and
//! parked on a condvar. Each call to [`WorkerPool::run`] is an
//! **epoch handoff**:
//!
//! 1. the caller publishes a task (a borrowed closure — no allocation,
//!    no `Arc`, no per-call channel) and bumps the epoch counter,
//! 2. every worker wakes, observes the new epoch, runs the task with
//!    its thread id and its private [`LocalStore`],
//! 3. each worker decrements the active count as soon as *it* finishes
//!    (the paper's merge: "it does not wait for the others" — there is
//!    no inter-worker barrier, only the caller waits for the last),
//! 4. the caller returns once the count hits zero, which is what makes
//!    the borrowed closure sound.
//!
//! Per-worker state lives in the worker's own [`LocalStore`], a typed
//! slot map keyed by attach id. State is **created on the worker's own
//! thread** (first-touch placement is real on NUMA hosts) and reused
//! across calls — the reusable working vectors, NUMA sub-array copies
//! and multi-RHS scratch of the executors above.

use std::any::Any;
use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

/// Poison-tolerant lock: a panic inside a pool *task* is caught and
/// re-raised on the caller, so a poisoned mutex only means some caller
/// unwound — the protected state is still consistent.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Poison-tolerant condvar wait (see [`lock`]).
fn wait<'a, T>(cv: &Condvar, g: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(g).unwrap_or_else(|e| e.into_inner())
}

/// Allocates process-unique ids for executors attaching per-worker
/// state to a pool (see [`LocalStore`]).
static NEXT_ATTACH_ID: AtomicU64 = AtomicU64::new(1);

/// Reserves a fresh attach id.
pub fn next_attach_id() -> u64 {
    NEXT_ATTACH_ID.fetch_add(1, Ordering::Relaxed)
}

/// Typed per-worker storage, owned by one worker thread and handed to
/// tasks through [`WorkerCtx`]. Keys are attach ids so several
/// executors can share one pool without clobbering each other's state.
#[derive(Default)]
pub struct LocalStore {
    slots: HashMap<u64, Box<dyn Any + Send>>,
}

impl LocalStore {
    /// The slot for `key`, created by `init` **on this worker thread**
    /// the first time it is touched (this is where NUMA first-touch
    /// placement actually happens).
    pub fn get_or_insert_with<S: Send + 'static>(
        &mut self,
        key: u64,
        init: impl FnOnce() -> S,
    ) -> &mut S {
        self.slots
            .entry(key)
            .or_insert_with(|| Box::new(init()))
            .downcast_mut::<S>()
            .expect("attach id reused with a different state type")
    }

    /// Drops the slot for `key` (detach).
    pub fn remove(&mut self, key: u64) {
        self.slots.remove(&key);
    }
}

/// What a task sees: which worker it is on, and that worker's state.
pub struct WorkerCtx<'a> {
    /// Worker index in `0..n_threads`.
    pub tid: usize,
    /// This worker's private storage (reusable scratch lives here).
    pub locals: &'a mut LocalStore,
}

/// A mutable buffer handed to the workers through a shared closure;
/// each worker reconstructs only its own **disjoint** sub-range
/// (per-span / per-chunk), which is what makes the paper's merge
/// syncless.
pub struct SendSlice<T> {
    ptr: *mut T,
    len: usize,
}

// SAFETY: workers only materialize pairwise-disjoint sub-ranges, each
// for the duration of one `run` call while the caller blocks on the
// original borrow.
unsafe impl<T: Send> Send for SendSlice<T> {}
unsafe impl<T: Send> Sync for SendSlice<T> {}

impl<T> SendSlice<T> {
    /// Captures a mutable slice for hand-off to one worker.
    pub fn new(s: &mut [T]) -> SendSlice<T> {
        SendSlice { ptr: s.as_mut_ptr(), len: s.len() }
    }

    /// Reconstructs the sub-slice `[start, end)` — how each worker
    /// carves its disjoint share out of one captured buffer without any
    /// per-call partition allocation.
    ///
    /// # Safety
    /// Ranges materialized across workers within one `run` epoch must
    /// be pairwise disjoint, and the original borrow must be held alive
    /// by the blocked caller.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn subslice_mut(&self, start: usize, end: usize) -> &mut [T] {
        debug_assert!(start <= end && end <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(start), end - start)
    }
}

/// The task pointer published for one epoch. The lifetime is erased;
/// soundness comes from `run` not returning before every worker is
/// done with the closure.
#[derive(Clone, Copy)]
struct SharedTask(&'static (dyn Fn(WorkerCtx<'_>) + Sync));

struct State {
    /// Bumped once per `run`; workers compare against their last-seen
    /// value, so a wake-up without new work is harmless.
    epoch: u64,
    /// Workers still computing the current epoch.
    active: usize,
    task: Option<SharedTask>,
    /// First panic payload of this epoch (resumed on the caller so the
    /// original message and location survive).
    panic: Option<Box<dyn Any + Send>>,
    shutdown: bool,
}

struct Inner {
    state: Mutex<State>,
    /// Workers park here between epochs.
    work_cv: Condvar,
    /// The caller parks here until `active == 0`.
    done_cv: Condvar,
}

/// A pool of persistent, parked worker threads (see module docs).
///
/// Created once (typically owned by an `SpmvEngine` for its lifetime,
/// shared with its executors via `Arc`); every SpMV/SpMM afterwards is
/// an epoch handoff with zero thread creation and zero allocation on
/// the pool's side.
pub struct WorkerPool {
    inner: Arc<Inner>,
    /// Serializes concurrent `run` callers (e.g. an engine shared
    /// across user threads): one epoch in flight at a time.
    run_lock: Mutex<()>,
    handles: Vec<std::thread::JoinHandle<()>>,
    n: usize,
}

impl WorkerPool {
    /// Spawns `n` workers, parked until the first [`WorkerPool::run`].
    pub fn new(n: usize) -> WorkerPool {
        assert!(n > 0);
        let inner = Arc::new(Inner {
            state: Mutex::new(State {
                epoch: 0,
                active: 0,
                task: None,
                panic: None,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let handles = (0..n)
            .map(|tid| {
                let inner = Arc::clone(&inner);
                std::thread::Builder::new()
                    .name(format!("spc5-pool-{tid}"))
                    .spawn(move || worker_loop(tid, &inner))
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool { inner, run_lock: Mutex::new(()), handles, n }
    }

    /// Number of workers.
    pub fn n_threads(&self) -> usize {
        self.n
    }

    /// Runs `task` on every worker (called with `tid` = `0..n`) and
    /// blocks until all are done. The closure may borrow caller state;
    /// writes go through disjoint [`SendSlice`]s.
    pub fn run(&self, task: impl Fn(WorkerCtx<'_>) + Sync) {
        let _serial = lock(&self.run_lock);
        let short: &(dyn Fn(WorkerCtx<'_>) + Sync) = &task;
        // SAFETY: the pointed-to closure outlives the epoch because we
        // do not return until `active == 0` (every worker has finished
        // calling it) — the classic scoped-pool lifetime erasure.
        let published: &'static (dyn Fn(WorkerCtx<'_>) + Sync) =
            unsafe { std::mem::transmute(short) };

        let mut st = lock(&self.inner.state);
        debug_assert_eq!(st.active, 0, "run_lock guarantees one epoch");
        st.task = Some(SharedTask(published));
        st.active = self.n;
        st.panic = None;
        st.epoch += 1;
        self.inner.work_cv.notify_all();
        while st.active > 0 {
            st = wait(&self.inner.done_cv, st);
        }
        st.task = None;
        if let Some(payload) = st.panic.take() {
            drop(st);
            std::panic::resume_unwind(payload);
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = lock(&self.inner.state);
            st.shutdown = true;
            self.inner.work_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(tid: usize, inner: &Inner) {
    let mut locals = LocalStore::default();
    let mut seen = 0u64;
    loop {
        let task = {
            let mut st = lock(&inner.state);
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    seen = st.epoch;
                    break st.task.expect("task published with epoch");
                }
                st = wait(&inner.work_cv, st);
            }
        };
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            // The `worker` injection site (global plan only): a panic
            // here is caught and re-raised on the caller exactly like
            // a real kernel panic, leaving the pool usable.
            crate::faults::fire_global(crate::faults::Site::Worker {
                worker: tid,
            });
            (task.0)(WorkerCtx { tid, locals: &mut locals })
        }));
        let mut st = lock(&inner.state);
        if let Err(payload) = outcome {
            st.panic.get_or_insert(payload);
        }
        st.active -= 1;
        if st.active == 0 {
            inner.done_cv.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn runs_every_worker_once_per_epoch() {
        let pool = WorkerPool::new(4);
        let hits = AtomicUsize::new(0);
        for _ in 0..100 {
            pool.run(|_ctx| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(hits.load(Ordering::Relaxed), 400);
    }

    #[test]
    fn workers_write_disjoint_slices() {
        let pool = WorkerPool::new(3);
        let mut y = vec![0usize; 9];
        let y_all = SendSlice::new(&mut y);
        pool.run(|ctx| {
            // SAFETY: one disjoint range per worker.
            let part =
                unsafe { y_all.subslice_mut(ctx.tid * 3, (ctx.tid + 1) * 3) };
            for v in part.iter_mut() {
                *v = ctx.tid + 1;
            }
        });
        assert_eq!(y, vec![1, 1, 1, 2, 2, 2, 3, 3, 3]);
    }

    #[test]
    fn locals_persist_across_epochs() {
        let pool = WorkerPool::new(2);
        let id = next_attach_id();
        // Each worker counts its own epochs in its LocalStore.
        for round in 1usize..=5 {
            let seen = Mutex::new(Vec::new());
            pool.run(|ctx| {
                let counter =
                    ctx.locals.get_or_insert_with(id, || 0usize);
                *counter += 1;
                seen.lock().unwrap().push(*counter);
            });
            let got = seen.into_inner().unwrap();
            assert_eq!(got, vec![round; 2], "round {round}");
        }
    }

    #[test]
    fn distinct_attach_ids_do_not_collide() {
        let pool = WorkerPool::new(2);
        let (a, b) = (next_attach_id(), next_attach_id());
        pool.run(|ctx| {
            *ctx.locals.get_or_insert_with(a, || 0usize) += 1;
            *ctx.locals.get_or_insert_with(b, || 100usize) += 1;
        });
        let check = Mutex::new(Vec::new());
        pool.run(|ctx| {
            let va = *ctx.locals.get_or_insert_with(a, || 0usize);
            let vb = *ctx.locals.get_or_insert_with(b, || 0usize);
            check.lock().unwrap().push((va, vb));
        });
        for (va, vb) in check.into_inner().unwrap() {
            assert_eq!((va, vb), (1, 101));
        }
    }

    #[test]
    fn pool_shuts_down_cleanly() {
        let pool = WorkerPool::new(3);
        pool.run(|_| {});
        drop(pool); // must not hang
    }

    #[test]
    fn worker_panic_propagates_to_caller() {
        let pool = WorkerPool::new(2);
        let res = catch_unwind(AssertUnwindSafe(|| {
            pool.run(|ctx| {
                if ctx.tid == 1 {
                    panic!("boom");
                }
            });
        }));
        assert!(res.is_err());
        // The pool must stay usable after a task panic.
        let hits = AtomicUsize::new(0);
        pool.run(|_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 2);
    }
}
