//! Threaded SpMV execution (paper §Parallelization), generic over the
//! element precision.
//!
//! Construction partitions the block matrix into per-thread spans with
//! the paper's balancing rule. Each call to [`ParallelSpmv::spmv`]
//! spawns scoped workers; each worker computes into its **own working
//! vector** and copies it into the disjoint slice of `y` it owns as
//! soon as it finishes — no barrier, no atomics, exactly the paper's
//! merge ("it does not wait for the others").
//!
//! [`ParallelStrategy::NumaSplit`] additionally gives every thread a
//! private *copy* of its sub-arrays (`values`, headers, rowptr), the
//! paper's NUMA optimization: on a multi-socket machine the per-thread
//! allocation lands on the local memory node by first touch. The
//! duplication cost and the structural consequences (matrix tied to the
//! thread count) are the trade-offs the paper discusses; both variants
//! are kept, like in SPC5.

use super::partition::{partition_intervals, ThreadSpan};
use crate::formats::{BlockMatrix, BlockSize};
use crate::kernels::avx512::Span;
use crate::kernels::scalar;
use crate::scalar::Scalar;

/// Memory placement strategy for the worker threads.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ParallelStrategy {
    /// All threads read the shared matrix arrays.
    Shared,
    /// Each thread owns a private copy of its sub-arrays (the paper's
    /// NUMA optimization).
    NumaSplit,
    /// NumaSplit plus a per-thread private copy of the `x` vector —
    /// the paper's conclusion asks to "assess the benefit and cost of
    /// duplicating the x vector on every memory node"; this mode
    /// measures exactly that trade (copy cost per call vs local reads).
    NumaSplitXCopy,
}

/// One thread's privately-owned sub-matrix (NumaSplit mode).
struct LocalPart<T: Scalar> {
    rowptr: Vec<u32>,
    headers: Vec<u8>,
    values: Vec<T>,
    rows: usize,
}

/// A parallel SpMV executor bound to one converted matrix.
pub struct ParallelSpmv<T: Scalar = f64> {
    bs: BlockSize,
    rows: usize,
    cols: usize,
    n_threads: usize,
    test: bool,
    spans: Vec<ThreadSpan>,
    val_ends: Vec<usize>,
    matrix: BlockMatrix<T>,
    locals: Vec<LocalPart<T>>,
    strategy: ParallelStrategy,
}

impl<T: Scalar> ParallelSpmv<T> {
    /// Builds the executor: partitions the matrix for `n_threads` and,
    /// in NumaSplit mode, materializes the per-thread copies.
    pub fn new(
        matrix: BlockMatrix<T>,
        n_threads: usize,
        strategy: ParallelStrategy,
        test: bool,
    ) -> Self {
        assert!(n_threads > 0);
        let spans = partition_intervals(&matrix, n_threads);
        // Value-range end per span = next span's begin (or total).
        let mut val_ends = Vec::with_capacity(spans.len());
        for (i, _s) in spans.iter().enumerate() {
            let end = if i + 1 < spans.len() {
                spans[i + 1].val_begin
            } else {
                matrix.values.len()
            };
            val_ends.push(end);
        }

        let locals = if strategy != ParallelStrategy::Shared {
            let stride = matrix.header_stride();
            spans
                .iter()
                .zip(&val_ends)
                .map(|(s, &ve)| {
                    // On a NUMA host each worker would run this copy
                    // itself after pinning (first-touch placement); the
                    // data layout is identical either way.
                    let rowptr: Vec<u32> = matrix.block_rowptr
                        [s.interval_begin..=s.interval_end]
                        .to_vec();
                    LocalPart {
                        rowptr,
                        headers: matrix.headers
                            [s.block_begin * stride..s.block_end * stride]
                            .to_vec(),
                        values: matrix.values[s.val_begin..ve].to_vec(),
                        rows: s.row_end - s.row_begin,
                    }
                })
                .collect()
        } else {
            Vec::new()
        };

        ParallelSpmv {
            bs: matrix.bs,
            rows: matrix.rows,
            cols: matrix.cols,
            n_threads,
            test,
            spans,
            val_ends,
            matrix,
            locals,
            strategy,
        }
    }

    /// Number of worker threads.
    pub fn n_threads(&self) -> usize {
        self.n_threads
    }

    /// The strategy this executor was built with.
    pub fn strategy(&self) -> ParallelStrategy {
        self.strategy
    }

    /// Underlying block matrix (shared arrays).
    pub fn matrix(&self) -> &BlockMatrix<T> {
        &self.matrix
    }

    /// Parallel `y += A·x`.
    pub fn spmv(&self, x: &[T], y: &mut [T]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);

        // Split y into per-span disjoint slices (the merge target).
        let mut y_parts: Vec<&mut [T]> = Vec::with_capacity(self.spans.len());
        let mut rest = y;
        let mut covered = 0usize;
        for s in &self.spans {
            let (part, tail) = rest.split_at_mut(s.row_end - covered);
            y_parts.push(part);
            rest = tail;
            covered = s.row_end;
        }

        std::thread::scope(|scope| {
            for (tid, y_part) in y_parts.into_iter().enumerate() {
                let s = self.spans[tid];
                scope.spawn(move || {
                    // Per-thread working vector (paper: "we pre-allocate
                    // a working vector of the same size").
                    let mut work = vec![T::ZERO; y_part.len()];
                    let span = self.span_view(tid, &s);
                    if self.strategy == ParallelStrategy::NumaSplitXCopy {
                        // Paper conclusion: duplicate x on every memory
                        // node. On NUMA the copy lands local by first
                        // touch; the copy cost is part of the measure.
                        let x_local = x.to_vec();
                        run_span(span, self.bs, &x_local, &mut work, self.test);
                    } else {
                        run_span(span, self.bs, x, &mut work, self.test);
                    }
                    // Syncless merge: this thread's rows are disjoint.
                    for (dst, w) in y_part.iter_mut().zip(&work) {
                        *dst += *w;
                    }
                });
            }
        });
    }

    fn span_view<'a>(&'a self, tid: usize, s: &ThreadSpan) -> Span<'a, T> {
        match self.strategy {
            ParallelStrategy::Shared => Span::slice(
                &self.matrix,
                s.interval_begin,
                s.interval_end,
                s.block_begin,
                s.block_end,
                s.val_begin,
                self.val_ends[tid],
            ),
            ParallelStrategy::NumaSplit | ParallelStrategy::NumaSplitXCopy => {
                let l = &self.locals[tid];
                Span {
                    rowptr: &l.rowptr,
                    headers: &l.headers,
                    values: &l.values,
                    rows: l.rows,
                    r: self.bs.r,
                }
            }
        }
    }
}

fn run_span<T: Scalar>(
    span: Span<'_, T>,
    bs: BlockSize,
    x: &[T],
    y: &mut [T],
    test: bool,
) {
    if span.rowptr.len() < 2 {
        return;
    }
    if crate::util::avx512_available()
        && T::spmv_span_simd(span, bs, x, y, test)
    {
        return;
    }
    // Portable fallback (the scalar span kernel ignores `test`; the
    // Algorithm-2 control flow only matters for performance).
    scalar::spmv_generic_span(span, bs, x, y);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::csr_to_block;
    use crate::matrix::{suite, Csr};

    fn check(
        csr: &Csr,
        bs: BlockSize,
        threads: usize,
        strategy: ParallelStrategy,
    ) {
        let bm = csr_to_block(csr, bs).unwrap();
        let p = ParallelSpmv::new(bm, threads, strategy, false);
        let x: Vec<f64> =
            (0..csr.cols).map(|i| ((i * 11) % 23) as f64 - 11.0).collect();
        let mut want = vec![0.0; csr.rows];
        csr.spmv_ref(&x, &mut want);
        let mut got = vec![0.0; csr.rows];
        p.spmv(&x, &mut got);
        for i in 0..csr.rows {
            assert!(
                (got[i] - want[i]).abs() <= 1e-9 * want[i].abs().max(1.0),
                "{bs} t={threads} {strategy:?} row {i}: {} vs {}",
                got[i],
                want[i]
            );
        }
    }

    #[test]
    fn shared_matches_reference() {
        for sm in suite::test_subset().iter().take(6) {
            for bs in [BlockSize::new(1, 8), BlockSize::new(4, 4)] {
                for threads in [1usize, 2, 4, 7] {
                    check(&sm.csr, bs, threads, ParallelStrategy::Shared);
                }
            }
        }
    }

    #[test]
    fn numa_split_matches_reference() {
        for sm in suite::test_subset().iter().take(6) {
            for bs in [BlockSize::new(2, 8), BlockSize::new(8, 4)] {
                for threads in [2usize, 5] {
                    check(&sm.csr, bs, threads, ParallelStrategy::NumaSplit);
                }
            }
        }
    }

    #[test]
    fn x_copy_mode_matches_reference() {
        for sm in suite::test_subset().iter().take(3) {
            check(
                &sm.csr,
                BlockSize::new(2, 4),
                3,
                ParallelStrategy::NumaSplitXCopy,
            );
        }
    }

    #[test]
    fn f32_parallel_matches_reference() {
        // The 16-lane f32 stack through the span-parallel runtime.
        for sm in suite::test_subset().iter().take(4) {
            let csr32: Csr<f32> = sm.csr.to_precision();
            for bs in [BlockSize::new(1, 16), BlockSize::new(4, 16)] {
                let bm = csr_to_block(&csr32, bs).unwrap();
                for strategy in
                    [ParallelStrategy::Shared, ParallelStrategy::NumaSplit]
                {
                    let p = ParallelSpmv::new(bm.clone(), 3, strategy, false);
                    let x: Vec<f32> = (0..csr32.cols)
                        .map(|i| ((i * 11) % 23) as f32 * 0.125 - 1.0)
                        .collect();
                    let mut want = vec![0.0f32; csr32.rows];
                    csr32.spmv_ref(&x, &mut want);
                    let mut got = vec![0.0f32; csr32.rows];
                    p.spmv(&x, &mut got);
                    for i in 0..csr32.rows {
                        assert!(
                            (got[i] - want[i]).abs()
                                <= 2e-4 * want[i].abs().max(1.0),
                            "{} {bs} {strategy:?} row {i}",
                            sm.name
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn more_threads_than_rows() {
        let csr = suite::poisson2d(3); // 9 rows
        check(&csr, BlockSize::new(4, 4), 16, ParallelStrategy::Shared);
        check(&csr, BlockSize::new(4, 4), 16, ParallelStrategy::NumaSplit);
    }

    #[test]
    fn test_variant_parallel() {
        let sm = &suite::test_subset()[4]; // circuit: many single blocks
        let bm = csr_to_block(&sm.csr, BlockSize::new(1, 8)).unwrap();
        let p = ParallelSpmv::new(bm, 4, ParallelStrategy::Shared, true);
        let x: Vec<f64> = (0..sm.csr.cols).map(|i| (i % 3) as f64).collect();
        let mut want = vec![0.0; sm.csr.rows];
        sm.csr.spmv_ref(&x, &mut want);
        let mut got = vec![0.0; sm.csr.rows];
        p.spmv(&x, &mut got);
        for i in 0..sm.csr.rows {
            assert!((got[i] - want[i]).abs() <= 1e-9 * want[i].abs().max(1.0));
        }
    }

    #[test]
    fn accumulates_into_existing_y() {
        let csr = suite::poisson2d(10);
        let bm = csr_to_block(&csr, BlockSize::new(2, 4)).unwrap();
        let p = ParallelSpmv::new(bm, 3, ParallelStrategy::Shared, false);
        let x = vec![1.0; csr.cols];
        let mut y = vec![5.0; csr.rows];
        p.spmv(&x, &mut y);
        let mut want = vec![5.0; csr.rows];
        csr.spmv_ref(&x, &mut want);
        for i in 0..csr.rows {
            assert!((y[i] - want[i]).abs() < 1e-12);
        }
    }
}
