//! Threaded SpMV/SpMM execution (paper §Parallelization), generic over
//! the element precision — a thin façade over the persistent
//! [`WorkerPool`] runtime.
//!
//! Construction partitions the block matrix into per-thread spans with
//! the paper's balancing rule and **attaches** it to the pool: each
//! worker builds its reusable working vector — and, in the NumaSplit
//! modes, its private `LocalPart` copy of its sub-arrays — **on its own
//! thread**, so first-touch NUMA placement is real (the old
//! `thread::scope` runtime copied on the constructing thread and spawned
//! fresh workers every call). Each call to [`ParallelSpmv::spmv`] is
//! then an epoch handoff: wake the parked workers, each computes into
//! its worker-owned vector and copies it into the disjoint slice of `y`
//! it owns as soon as it finishes — no barrier between workers, no
//! atomics, exactly the paper's merge ("it does not wait for the
//! others") — with **no thread spawn and no allocation per call**.
//!
//! [`ParallelStrategy::NumaSplit`] additionally gives every thread a
//! private *copy* of its sub-arrays (`values`, headers, rowptr), the
//! paper's NUMA optimization. The duplication cost and the structural
//! consequences (matrix tied to the thread count) are the trade-offs
//! the paper discusses; both variants are kept, like in SPC5.
//!
//! [`ParallelSpmv::spmm`] runs the multi-RHS product (`Y += A·X`, `k`
//! right-hand sides in one matrix traversal) over the same spans and
//! scratch — the batched path the serving layer coalesces concurrent
//! requests into.

use super::partition::{partition_intervals, ThreadSpan};
use super::pool::{next_attach_id, SendSlice, WorkerCtx, WorkerPool};
use crate::formats::{BlockMatrix, BlockSize};
use crate::kernels::avx512::Span;
use crate::kernels::{scalar, spmm};
use crate::scalar::Scalar;
use std::sync::Arc;

/// Memory placement strategy for the worker threads.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ParallelStrategy {
    /// All threads read the shared matrix arrays.
    Shared,
    /// Each thread owns a private copy of its sub-arrays (the paper's
    /// NUMA optimization), materialized on the worker's own thread.
    NumaSplit,
    /// NumaSplit plus a per-thread private copy of the `x` vector —
    /// the paper's conclusion asks to "assess the benefit and cost of
    /// duplicating the x vector on every memory node"; this mode
    /// measures exactly that trade (copy cost per call vs local reads).
    NumaSplitXCopy,
}

/// One thread's privately-owned sub-matrix (NumaSplit mode).
struct LocalPart<T: Scalar> {
    rowptr: Vec<u32>,
    headers: Vec<u8>,
    values: Vec<T>,
    rows: usize,
}

/// One worker's persistent, reusable state: the working vector the
/// paper pre-allocates, the x-copy buffer (XCopy mode), the multi-RHS
/// accumulator scratch and the NUMA sub-array copies. Lives in the
/// worker's `LocalStore`, created and touched only on that worker's
/// thread.
struct WorkerLocal<T: Scalar> {
    work: Vec<T>,
    xbuf: Vec<T>,
    /// `r·k` interval accumulators for the portable SpMM span kernel.
    mrhs: Vec<T>,
    part: Option<LocalPart<T>>,
}

/// A parallel SpMV/SpMM executor bound to one converted matrix and one
/// [`WorkerPool`].
pub struct ParallelSpmv<T: Scalar = f64> {
    bs: BlockSize,
    rows: usize,
    cols: usize,
    test: bool,
    spans: Vec<ThreadSpan>,
    val_ends: Vec<usize>,
    matrix: BlockMatrix<T>,
    strategy: ParallelStrategy,
    pool: Arc<WorkerPool>,
    attach_id: u64,
}

impl<T: Scalar> ParallelSpmv<T> {
    /// Convenience constructor owning a fresh pool of `n_threads`
    /// workers. Prefer [`ParallelSpmv::with_pool`] when a longer-lived
    /// pool exists (the engine shares one across all its paths).
    pub fn new(
        matrix: BlockMatrix<T>,
        n_threads: usize,
        strategy: ParallelStrategy,
        test: bool,
    ) -> Self {
        assert!(n_threads > 0);
        Self::with_pool(
            matrix,
            Arc::new(WorkerPool::new(n_threads)),
            strategy,
            test,
        )
    }

    /// Builds the executor on an existing pool: partitions the matrix
    /// across the pool's workers and attaches — every worker creates
    /// its reusable scratch (and, in NumaSplit modes, its first-touch
    /// `LocalPart` copy) on its own thread before this returns.
    pub fn with_pool(
        matrix: BlockMatrix<T>,
        pool: Arc<WorkerPool>,
        strategy: ParallelStrategy,
        test: bool,
    ) -> Self {
        let spans = partition_intervals(&matrix, pool.n_threads());
        // Value-range end per span = next span's begin (or total).
        let mut val_ends = Vec::with_capacity(spans.len());
        for (i, _s) in spans.iter().enumerate() {
            let end = if i + 1 < spans.len() {
                spans[i + 1].val_begin
            } else {
                matrix.values.len()
            };
            val_ends.push(end);
        }

        let p = ParallelSpmv {
            bs: matrix.bs,
            rows: matrix.rows,
            cols: matrix.cols,
            test,
            spans,
            val_ends,
            matrix,
            strategy,
            pool,
            attach_id: next_attach_id(),
        };
        // Attach: each worker materializes its own state in place.
        p.pool.run(|ctx: WorkerCtx<'_>| {
            let tid = ctx.tid;
            ctx.locals
                .get_or_insert_with(p.attach_id, || p.build_local(tid));
        });
        p
    }

    /// Number of worker threads.
    pub fn n_threads(&self) -> usize {
        self.pool.n_threads()
    }

    /// The strategy this executor was built with.
    pub fn strategy(&self) -> ParallelStrategy {
        self.strategy
    }

    /// Underlying block matrix (shared arrays).
    pub fn matrix(&self) -> &BlockMatrix<T> {
        &self.matrix
    }

    /// The pool this executor runs on.
    pub fn pool(&self) -> &Arc<WorkerPool> {
        &self.pool
    }

    /// Whether this executor runs the Algorithm-2 `test` kernel
    /// variant.
    pub fn algo2_test(&self) -> bool {
        self.test
    }

    /// Builds one worker's persistent state. Called on the worker's own
    /// thread (attach time, or lazily if the slot was evicted), so the
    /// copies land on the local memory node by first touch.
    fn build_local(&self, tid: usize) -> WorkerLocal<T> {
        let part = if self.strategy != ParallelStrategy::Shared {
            let s = &self.spans[tid];
            let ve = self.val_ends[tid];
            let stride = self.matrix.header_stride();
            Some(LocalPart {
                rowptr: self.matrix.block_rowptr
                    [s.interval_begin..=s.interval_end]
                    .to_vec(),
                headers: self.matrix.headers
                    [s.block_begin * stride..s.block_end * stride]
                    .to_vec(),
                values: self.matrix.values[s.val_begin..ve].to_vec(),
                rows: s.row_end - s.row_begin,
            })
        } else {
            None
        };
        WorkerLocal {
            work: Vec::new(),
            xbuf: Vec::new(),
            mrhs: Vec::new(),
            part,
        }
    }

    /// Parallel `y += A·x` — one pool epoch, no spawn, no allocation
    /// (worker scratch is reused across calls; each worker carves its
    /// disjoint span rows out of `y` itself).
    pub fn spmv(&self, x: &[T], y: &mut [T]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        let y_all = SendSlice::new(y);
        self.pool
            .run(|ctx: WorkerCtx<'_>| self.worker_pass(ctx, &y_all, x, 1));
    }

    /// Parallel multi-RHS `Y += A·X` with `X`/`Y` row-major
    /// `[cols × k]` / `[rows × k]` (see [`crate::kernels::spmm`]):
    /// one traversal of the matrix serves all `k` right-hand sides.
    ///
    /// Note: the Algorithm-2 `test` traversal has no multi-RHS
    /// counterpart, so a `BetaTest` executor serves `k > 1` through the
    /// standard SpMM traversal — the result is identical (same
    /// products, same per-interval accumulation order); only the
    /// single-value branch-prediction trick is specific to `k == 1`.
    pub fn spmm(&self, x: &[T], y: &mut [T], k: usize) {
        assert!(k > 0);
        assert_eq!(x.len(), self.cols * k, "x must be cols*k");
        assert_eq!(y.len(), self.rows * k, "y must be rows*k");
        if k == 1 {
            return self.spmv(x, y);
        }
        let y_all = SendSlice::new(y);
        self.pool
            .run(|ctx: WorkerCtx<'_>| self.worker_pass(ctx, &y_all, x, k));
    }

    /// One worker's share of an SpMV (`k == 1`) or SpMM (`k > 1`)
    /// epoch: compute the span into the reusable working vector, then
    /// merge into the disjoint `y` part (syncless — rows are disjoint).
    fn worker_pass(
        &self,
        ctx: WorkerCtx<'_>,
        y_all: &SendSlice<T>,
        x: &[T],
        k: usize,
    ) {
        let tid = ctx.tid;
        let local: &mut WorkerLocal<T> = ctx
            .locals
            .get_or_insert_with(self.attach_id, || self.build_local(tid));
        let WorkerLocal { work, xbuf, mrhs, part } = local;

        let s = &self.spans[tid];
        let span = match self.strategy {
            ParallelStrategy::Shared => Span::slice(
                &self.matrix,
                s.interval_begin,
                s.interval_end,
                s.block_begin,
                s.block_end,
                s.val_begin,
                self.val_ends[tid],
            ),
            ParallelStrategy::NumaSplit
            | ParallelStrategy::NumaSplitXCopy => {
                let l = part.as_ref().expect("NumaSplit local attached");
                Span {
                    rowptr: &l.rowptr,
                    headers: &l.headers,
                    values: &l.values,
                    rows: l.rows,
                    r: self.bs.r,
                }
            }
        };

        // SAFETY: spans are contiguous and disjoint across workers, so
        // each worker's row range aliases nothing; the borrow is alive
        // while the caller blocks in `run`.
        let y_part =
            unsafe { y_all.subslice_mut(s.row_begin * k, s.row_end * k) };
        // Reusable working vector (paper: "we pre-allocate a working
        // vector of the same size") — zeroed, not reallocated.
        work.clear();
        work.resize(y_part.len(), T::ZERO);

        let xs: &[T] = if self.strategy == ParallelStrategy::NumaSplitXCopy
        {
            // Paper conclusion: duplicate x on every memory node. The
            // worker-owned buffer lands local by first touch; the copy
            // cost per call is part of the measure.
            xbuf.clear();
            xbuf.extend_from_slice(x);
            xbuf
        } else {
            x
        };

        if k == 1 {
            run_span(span, self.bs, xs, work, self.test, self.matrix.tune);
        } else {
            spmm::spmm_span_scratch_tuned(
                span,
                self.bs,
                xs,
                work,
                k,
                mrhs,
                self.matrix.tune,
            );
        }
        // Syncless merge: this thread's rows are disjoint.
        for (dst, w) in y_part.iter_mut().zip(work.iter()) {
            *dst += *w;
        }
    }
}

impl<T: Scalar> Drop for ParallelSpmv<T> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            return;
        }
        // Detach: release the per-worker scratch held under our id.
        let id = self.attach_id;
        self.pool.run(|ctx: WorkerCtx<'_>| ctx.locals.remove(id));
    }
}

fn run_span<T: Scalar>(
    span: Span<'_, T>,
    bs: BlockSize,
    x: &[T],
    y: &mut [T],
    test: bool,
    tune: crate::kernels::avx512::TuneParams,
) {
    if span.rowptr.len() < 2 {
        return;
    }
    if crate::util::avx512_available()
        && T::spmv_span_simd(span, bs, x, y, test, tune)
    {
        return;
    }
    // Portable fallback (the scalar span kernel ignores `test`; the
    // Algorithm-2 control flow only matters for performance).
    scalar::spmv_generic_span(span, bs, x, y);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::csr_to_block;
    use crate::matrix::{suite, Csr};

    fn check(
        csr: &Csr,
        bs: BlockSize,
        threads: usize,
        strategy: ParallelStrategy,
    ) {
        let bm = csr_to_block(csr, bs).unwrap();
        let p = ParallelSpmv::new(bm, threads, strategy, false);
        let x: Vec<f64> =
            (0..csr.cols).map(|i| ((i * 11) % 23) as f64 - 11.0).collect();
        let mut want = vec![0.0; csr.rows];
        csr.spmv_ref(&x, &mut want);
        let mut got = vec![0.0; csr.rows];
        p.spmv(&x, &mut got);
        for i in 0..csr.rows {
            assert!(
                (got[i] - want[i]).abs() <= 1e-9 * want[i].abs().max(1.0),
                "{bs} t={threads} {strategy:?} row {i}: {} vs {}",
                got[i],
                want[i]
            );
        }
    }

    #[test]
    fn shared_matches_reference() {
        for sm in suite::test_subset().iter().take(6) {
            for bs in [BlockSize::new(1, 8), BlockSize::new(4, 4)] {
                for threads in [1usize, 2, 4, 7] {
                    check(&sm.csr, bs, threads, ParallelStrategy::Shared);
                }
            }
        }
    }

    #[test]
    fn numa_split_matches_reference() {
        for sm in suite::test_subset().iter().take(6) {
            for bs in [BlockSize::new(2, 8), BlockSize::new(8, 4)] {
                for threads in [2usize, 5] {
                    check(&sm.csr, bs, threads, ParallelStrategy::NumaSplit);
                }
            }
        }
    }

    #[test]
    fn x_copy_mode_matches_reference() {
        for sm in suite::test_subset().iter().take(3) {
            check(
                &sm.csr,
                BlockSize::new(2, 4),
                3,
                ParallelStrategy::NumaSplitXCopy,
            );
        }
    }

    #[test]
    fn f32_parallel_matches_reference() {
        // The 16-lane f32 stack through the span-parallel runtime.
        for sm in suite::test_subset().iter().take(4) {
            let csr32: Csr<f32> = sm.csr.to_precision();
            for bs in [BlockSize::new(1, 16), BlockSize::new(4, 16)] {
                let bm = csr_to_block(&csr32, bs).unwrap();
                for strategy in
                    [ParallelStrategy::Shared, ParallelStrategy::NumaSplit]
                {
                    let p = ParallelSpmv::new(bm.clone(), 3, strategy, false);
                    let x: Vec<f32> = (0..csr32.cols)
                        .map(|i| ((i * 11) % 23) as f32 * 0.125 - 1.0)
                        .collect();
                    let mut want = vec![0.0f32; csr32.rows];
                    csr32.spmv_ref(&x, &mut want);
                    let mut got = vec![0.0f32; csr32.rows];
                    p.spmv(&x, &mut got);
                    for i in 0..csr32.rows {
                        assert!(
                            (got[i] - want[i]).abs()
                                <= 2e-4 * want[i].abs().max(1.0),
                            "{} {bs} {strategy:?} row {i}",
                            sm.name
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn more_threads_than_rows() {
        let csr = suite::poisson2d(3); // 9 rows
        check(&csr, BlockSize::new(4, 4), 16, ParallelStrategy::Shared);
        check(&csr, BlockSize::new(4, 4), 16, ParallelStrategy::NumaSplit);
    }

    #[test]
    fn test_variant_parallel() {
        let sm = &suite::test_subset()[4]; // circuit: many single blocks
        let bm = csr_to_block(&sm.csr, BlockSize::new(1, 8)).unwrap();
        let p = ParallelSpmv::new(bm, 4, ParallelStrategy::Shared, true);
        let x: Vec<f64> = (0..sm.csr.cols).map(|i| (i % 3) as f64).collect();
        let mut want = vec![0.0; sm.csr.rows];
        sm.csr.spmv_ref(&x, &mut want);
        let mut got = vec![0.0; sm.csr.rows];
        p.spmv(&x, &mut got);
        for i in 0..sm.csr.rows {
            assert!((got[i] - want[i]).abs() <= 1e-9 * want[i].abs().max(1.0));
        }
    }

    #[test]
    fn accumulates_into_existing_y() {
        let csr = suite::poisson2d(10);
        let bm = csr_to_block(&csr, BlockSize::new(2, 4)).unwrap();
        let p = ParallelSpmv::new(bm, 3, ParallelStrategy::Shared, false);
        let x = vec![1.0; csr.cols];
        let mut y = vec![5.0; csr.rows];
        p.spmv(&x, &mut y);
        let mut want = vec![5.0; csr.rows];
        csr.spmv_ref(&x, &mut want);
        for i in 0..csr.rows {
            assert!((y[i] - want[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn repeated_calls_reuse_the_same_pool() {
        // Many SpMVs over one executor: results stay exact and no state
        // leaks between epochs (the reused scratch is re-zeroed).
        let csr = suite::poisson2d(14);
        let bm = csr_to_block(&csr, BlockSize::new(2, 8)).unwrap();
        let p = ParallelSpmv::new(bm, 4, ParallelStrategy::Shared, false);
        for round in 0..20u64 {
            let x: Vec<f64> = (0..csr.cols)
                .map(|i| ((i as u64 + round) % 13) as f64 * 0.25 - 1.0)
                .collect();
            let mut want = vec![0.0; csr.rows];
            csr.spmv_ref(&x, &mut want);
            let mut got = vec![0.0; csr.rows];
            p.spmv(&x, &mut got);
            crate::testkit::assert_close(&got, &want, 1e-9, "reuse");
        }
    }

    #[test]
    fn two_executors_share_one_pool() {
        // Engine-style sharing: one pool, two attached matrices with
        // different strategies; attach ids keep their scratch apart.
        let pool = Arc::new(WorkerPool::new(3));
        let a = suite::poisson2d(12);
        let b = suite::fem_blocked(200, 3, 5, 17);
        let pa = ParallelSpmv::with_pool(
            csr_to_block(&a, BlockSize::new(1, 8)).unwrap(),
            Arc::clone(&pool),
            ParallelStrategy::Shared,
            false,
        );
        let pb = ParallelSpmv::with_pool(
            csr_to_block(&b, BlockSize::new(2, 4)).unwrap(),
            Arc::clone(&pool),
            ParallelStrategy::NumaSplit,
            false,
        );
        for (csr, p) in [(&a, &pa), (&b, &pb), (&a, &pa)] {
            let x: Vec<f64> =
                (0..csr.cols).map(|i| (i % 7) as f64 - 3.0).collect();
            let mut want = vec![0.0; csr.rows];
            csr.spmv_ref(&x, &mut want);
            let mut got = vec![0.0; csr.rows];
            p.spmv(&x, &mut got);
            crate::testkit::assert_close(&got, &want, 1e-9, "shared pool");
        }
    }

    #[test]
    fn parallel_spmm_matches_k_single_spmvs() {
        let csr = suite::quantum_clusters(300, 3, 8, 5, 11);
        let bm = csr_to_block(&csr, BlockSize::new(2, 8)).unwrap();
        let p = ParallelSpmv::new(bm, 4, ParallelStrategy::Shared, false);
        for k in [2usize, 3, 8] {
            let x: Vec<f64> = (0..csr.cols * k)
                .map(|i| ((i * 7) % 19) as f64 * 0.1 - 0.9)
                .collect();
            let mut y = vec![0.0; csr.rows * k];
            p.spmm(&x, &mut y, k);
            // Oracle: k independent single-vector products.
            for j in 0..k {
                let xj: Vec<f64> =
                    (0..csr.cols).map(|c| x[c * k + j]).collect();
                let mut want = vec![0.0; csr.rows];
                csr.spmv_ref(&xj, &mut want);
                for r in 0..csr.rows {
                    assert!(
                        (y[r * k + j] - want[r]).abs()
                            <= 1e-9 * want[r].abs().max(1.0),
                        "k={k} j={j} row {r}"
                    );
                }
            }
        }
    }

    #[test]
    fn parallel_spmm_numa_split_matches() {
        let csr = suite::fem_blocked(240, 3, 6, 13);
        let bm = csr_to_block(&csr, BlockSize::new(4, 4)).unwrap();
        let p = ParallelSpmv::new(bm, 3, ParallelStrategy::NumaSplit, false);
        let k = 4usize;
        let x: Vec<f64> = (0..csr.cols * k)
            .map(|i| ((i * 5) % 17) as f64 * 0.2 - 1.5)
            .collect();
        let mut y = vec![0.0; csr.rows * k];
        p.spmm(&x, &mut y, k);
        for j in 0..k {
            let xj: Vec<f64> = (0..csr.cols).map(|c| x[c * k + j]).collect();
            let mut want = vec![0.0; csr.rows];
            csr.spmv_ref(&xj, &mut want);
            for r in 0..csr.rows {
                assert!(
                    (y[r * k + j] - want[r]).abs()
                        <= 1e-9 * want[r].abs().max(1.0),
                    "j={j} row {r}"
                );
            }
        }
    }
}
