//! Level scheduling for sparse triangular structure.
//!
//! A triangular solve looks inherently sequential — row `r` needs the
//! solution of every row its off-diagonal entries reference — but the
//! dependency DAG is usually shallow and wide: rows with no mutual
//! dependency can solve concurrently. [`lower_levels`] /
//! [`upper_levels`] compute the classic *level sets* (row `r`'s level
//! is one past the deepest level it depends on), and [`run_levels`]
//! executes them level-by-level on the existing
//! [`WorkerPool`](crate::parallel::WorkerPool): levels run in sequence,
//! the rows of one level split across the workers.
//!
//! Execution preserves bit-identity with the sequential kernels: each
//! row's value is computed by the same per-row closure reading only
//! rows from strictly earlier levels (plus read-only inputs), so the
//! floating-point accumulation per row is unchanged — only the order
//! *across* independent rows differs, and no row reads another row of
//! its own level.
//!
//! Whether per-level parallelism is worth the epoch handoffs is a
//! property of the schedule, not the matrix class:
//! [`LevelSchedule::parallel_worthwhile`] applies a width heuristic,
//! and the decision is recorded in a [`LevelSummary`] so a
//! [`crate::coordinator::SolvePlan`] can replay it on a repeat solve
//! without re-running the analysis.

use crate::matrix::Csr;
use crate::parallel::WorkerPool;
use crate::scalar::Scalar;
use crate::util::ceil_div;

/// Dependency level sets of a triangular matrix: rows grouped by level,
/// ascending row order within each level.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LevelSchedule {
    /// `level_ptr[l]..level_ptr[l+1]` indexes [`LevelSchedule::rows`];
    /// length `n_levels + 1`.
    pub level_ptr: Vec<u32>,
    /// Row indices grouped by level (a permutation of `0..n`).
    pub rows: Vec<u32>,
}

impl LevelSchedule {
    /// Number of levels (sequential phases).
    pub fn n_levels(&self) -> usize {
        self.level_ptr.len() - 1
    }

    /// The rows of level `l`, ascending.
    pub fn level(&self, l: usize) -> &[u32] {
        &self.rows[self.level_ptr[l] as usize..self.level_ptr[l + 1] as usize]
    }

    /// Widest level (peak available parallelism).
    pub fn max_width(&self) -> usize {
        (0..self.n_levels()).map(|l| self.level(l).len()).max().unwrap_or(0)
    }

    /// Mean rows per level.
    pub fn avg_width(&self) -> f64 {
        if self.n_levels() == 0 {
            0.0
        } else {
            self.rows.len() as f64 / self.n_levels() as f64
        }
    }

    /// Whether level-parallel execution is expected to beat the
    /// sequential solve at `threads` workers: each level must carry
    /// enough rows on average to amortize one pool epoch handoff.
    /// Deliberately conservative — a wrong "no" costs a little
    /// parallelism, a wrong "yes" pays `n_levels` epoch handoffs for
    /// nothing.
    pub fn parallel_worthwhile(&self, threads: usize) -> bool {
        threads > 1
            && self.n_levels() > 1
            && self.avg_width() >= (4 * threads) as f64
    }

    /// Condenses the analysis into the serializable form a
    /// [`crate::coordinator::SolvePlan`] records.
    pub fn summary(&self, parallel: bool) -> LevelSummary {
        LevelSummary {
            n_levels: self.n_levels(),
            max_width: self.max_width(),
            parallel,
        }
    }
}

/// What a repeat solve needs to know about a level analysis without
/// redoing it: the schedule shape and the sequential-vs-parallel
/// decision taken. Serialized inside
/// [`crate::coordinator::SolvePlan`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LevelSummary {
    pub n_levels: usize,
    pub max_width: usize,
    /// Whether level-parallel execution was chosen.
    pub parallel: bool,
}

/// Builds the level sets of a **strict lower** triangular matrix:
/// `level[r] = 1 + max(level[c])` over row `r`'s columns (all `< r`),
/// `0` when the row has none — the forward-substitution dependency
/// order.
pub fn lower_levels<T: Scalar>(lower: &Csr<T>) -> LevelSchedule {
    let n = lower.rows;
    let mut level = vec![0u32; n];
    let mut n_levels = 0u32;
    for r in 0..n {
        let mut lvl = 0u32;
        for k in lower.row_range(r) {
            debug_assert!((lower.colidx[k] as usize) < r, "not strict lower");
            lvl = lvl.max(level[lower.colidx[k] as usize] + 1);
        }
        level[r] = lvl;
        n_levels = n_levels.max(lvl + 1);
    }
    bucket_by_level(&level, n_levels)
}

/// Builds the level sets of a **strict upper** triangular matrix:
/// dependencies are columns `> r`, computed rows-descending — the
/// backward-substitution dependency order.
pub fn upper_levels<T: Scalar>(upper: &Csr<T>) -> LevelSchedule {
    let n = upper.rows;
    let mut level = vec![0u32; n];
    let mut n_levels = if n == 0 { 0 } else { 1 };
    for r in (0..n).rev() {
        let mut lvl = 0u32;
        for k in upper.row_range(r) {
            debug_assert!((upper.colidx[k] as usize) > r, "not strict upper");
            lvl = lvl.max(level[upper.colidx[k] as usize] + 1);
        }
        level[r] = lvl;
        n_levels = n_levels.max(lvl + 1);
    }
    bucket_by_level(&level, n_levels)
}

/// Counting-sorts rows into their levels, ascending row order within
/// each level.
fn bucket_by_level(level: &[u32], n_levels: u32) -> LevelSchedule {
    let nl = n_levels as usize;
    let mut level_ptr = vec![0u32; nl + 1];
    for &l in level {
        level_ptr[l as usize + 1] += 1;
    }
    for l in 0..nl {
        let prev = level_ptr[l];
        level_ptr[l + 1] += prev;
    }
    let mut rows = vec![0u32; level.len()];
    let mut next = level_ptr.clone();
    for (r, &l) in level.iter().enumerate() {
        rows[next[l as usize] as usize] = r as u32;
        next[l as usize] += 1;
    }
    LevelSchedule { level_ptr, rows }
}

/// Read-only view of the solution vector handed to per-row closures in
/// [`run_levels`]. Reads must target rows of strictly earlier levels
/// (which the level construction guarantees for triangular
/// dependencies) or data no level writes.
pub struct RowReader<'a, T> {
    ptr: *const T,
    len: usize,
    _marker: std::marker::PhantomData<&'a T>,
}

impl<T: Copy> RowReader<'_, T> {
    /// The current value of `x[i]`.
    #[inline]
    pub fn get(&self, i: usize) -> T {
        debug_assert!(i < self.len);
        // SAFETY: `i` is in bounds and, per the level-set invariant,
        // no concurrently-running row writes index `i` (writers only
        // touch their own level's rows; dependencies live in earlier,
        // already-completed levels).
        unsafe { *self.ptr.add(i) }
    }
}

/// Shared mutable handle for the level executor (one disjoint row per
/// in-flight closure call).
struct SharedX<T>(*mut T, usize);
// SAFETY: every write targets a distinct row of the current level and
// reads target completed levels; the caller blocks until each epoch
// finishes, holding the original borrow alive.
unsafe impl<T: Send> Send for SharedX<T> {}
unsafe impl<T: Send> Sync for SharedX<T> {}

/// Executes one level-scheduled sweep: for each level in order, runs
/// `row_value(row, reader)` for every row of the level across the
/// pool's workers and stores the result into `x[row]`. The closure
/// must read `x` only through the [`RowReader`] and only at rows of
/// strictly earlier levels.
pub fn run_levels<T: Scalar>(
    pool: &WorkerPool,
    sched: &LevelSchedule,
    x: &mut [T],
    row_value: impl Fn(usize, &RowReader<'_, T>) -> T + Sync,
) {
    let shared = SharedX(x.as_mut_ptr(), x.len());
    let nt = pool.n_threads();
    for l in 0..sched.n_levels() {
        let rows = sched.level(l);
        if rows.is_empty() {
            continue;
        }
        // Shallow levels run on the calling thread: an epoch handoff
        // per handful of rows costs more than it buys.
        if rows.len() < 2 * nt {
            let reader = RowReader {
                ptr: shared.0 as *const T,
                len: shared.1,
                _marker: std::marker::PhantomData,
            };
            for &r in rows {
                let v = row_value(r as usize, &reader);
                // SAFETY: single-threaded here; `r` is in bounds.
                unsafe { *shared.0.add(r as usize) = v };
            }
            continue;
        }
        pool.run(|ctx| {
            let chunk = ceil_div(rows.len(), nt);
            let a = (ctx.tid * chunk).min(rows.len());
            let b = (a + chunk).min(rows.len());
            let reader = RowReader {
                ptr: shared.0 as *const T,
                len: shared.1,
                _marker: std::marker::PhantomData,
            };
            for &r in &rows[a..b] {
                let v = row_value(r as usize, &reader);
                // SAFETY: rows within a level are distinct, so each
                // write is exclusive; reads go through the reader to
                // earlier levels only.
                unsafe { *shared.0.add(r as usize) = v };
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::suite;

    #[test]
    fn poisson_levels_are_antidiagonals() {
        // 2-D Poisson's strict lower part links (i,j) to (i-1,j) and
        // (i,j-1): the level of grid point (i,j) is i+j, so an n×n
        // grid has 2n-1 levels with max width n.
        let n = 10;
        let split = suite::poisson2d(n).triangular_split().unwrap();
        let sched = lower_levels(&split.lower);
        assert_eq!(sched.n_levels(), 2 * n - 1);
        assert_eq!(sched.max_width(), n);
        assert_eq!(sched.rows.len(), n * n);
        // Upper part mirrors it.
        let up = upper_levels(&split.upper);
        assert_eq!(up.n_levels(), 2 * n - 1);
        assert_eq!(up.max_width(), n);
    }

    #[test]
    fn diagonal_matrix_is_one_level() {
        // No off-diagonal entries → every row at level 0.
        let split = suite::poisson2d(6).triangular_split().unwrap();
        let empty = crate::matrix::Csr::<f64> {
            rows: split.lower.rows,
            cols: split.lower.cols,
            rowptr: vec![0; split.lower.rows + 1],
            colidx: vec![],
            values: vec![],
        };
        let sched = lower_levels(&empty);
        assert_eq!(sched.n_levels(), 1);
        assert_eq!(sched.max_width(), empty.rows);
        assert!(!sched.parallel_worthwhile(4), "single level, no deps");
    }

    #[test]
    fn levels_respect_dependencies() {
        for sm in suite::test_subset() {
            if sm.csr.rows != sm.csr.cols {
                continue;
            }
            let split = sm.csr.triangular_split().unwrap();
            let sched = lower_levels(&split.lower);
            let mut level_of = vec![u32::MAX; split.n()];
            for l in 0..sched.n_levels() {
                for &r in sched.level(l) {
                    assert_eq!(
                        level_of[r as usize],
                        u32::MAX,
                        "row {r} in two levels ({})",
                        sm.name
                    );
                    level_of[r as usize] = l as u32;
                }
            }
            assert!(
                level_of.iter().all(|&l| l != u32::MAX),
                "{}: uncovered rows",
                sm.name
            );
            for r in 0..split.n() {
                for k in split.lower.row_range(r) {
                    let c = split.lower.colidx[k] as usize;
                    assert!(
                        level_of[c] < level_of[r],
                        "{}: dep {c}→{r} not in earlier level",
                        sm.name
                    );
                }
            }
        }
    }

    #[test]
    fn run_levels_matches_sequential_recurrence() {
        // x[r] = b[r] + sum of x over the strict-lower pattern — the
        // executor must reproduce the sequential recurrence exactly.
        let split = suite::poisson2d(12).triangular_split().unwrap();
        let n = split.n();
        let b: Vec<f64> = (0..n).map(|i| ((i * 13) % 7) as f64 - 3.0).collect();
        let mut want = vec![0.0f64; n];
        for r in 0..n {
            let mut s = b[r];
            for k in split.lower.row_range(r) {
                s += want[split.lower.colidx[k] as usize];
            }
            want[r] = s;
        }
        let sched = lower_levels(&split.lower);
        let pool = WorkerPool::new(4);
        let mut got = vec![0.0f64; n];
        run_levels(&pool, &sched, &mut got, |r, rd| {
            let mut s = b[r];
            for k in split.lower.row_range(r) {
                s += rd.get(split.lower.colidx[k] as usize);
            }
            s
        });
        assert_eq!(got, want);
    }

    #[test]
    fn summary_records_shape_and_decision() {
        let split = suite::poisson2d(16).triangular_split().unwrap();
        let sched = lower_levels(&split.lower);
        let s = sched.summary(sched.parallel_worthwhile(4));
        assert_eq!(s.n_levels, 31);
        assert_eq!(s.max_width, 16);
        assert!(!s.parallel, "avg width 256/31 < 16");
        let wide = suite::poisson2d(64).triangular_split().unwrap();
        let wide_sched = lower_levels(&wide.lower);
        assert!(wide_sched.parallel_worthwhile(4), "avg width ≈ 32");
    }
}
