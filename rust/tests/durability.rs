//! Crash-consistency and corruption-differential suite for the
//! durable state tier.
//!
//! The contract under test: every persisted artifact (saved plan,
//! plan cache, record store, tune profile, bench report) survives
//! adversarial on-disk state — a single flipped bit at *any* offset, a
//! torn (partially written) file, a zero-length file, a pre-envelope
//! legacy file — with a typed [`spc5::util::StateError`], a quarantined
//! corpse, and a degraded-but-correct cold start that serves results
//! bit-identical to a never-cached run. Never a panic, never silently
//! wrong state.
//!
//! The tests share one process, and the torn-write tests install a
//! process-global fault plan, so every test that touches the durable
//! layer serializes on [`LOCK`].

use std::path::{Path, PathBuf};
use std::sync::Mutex;

use spc5::coordinator::{SpmvEngine, SpmvPlan};
use spc5::matrix::suite;
use spc5::predictor::{PerfRecord, RecordStore};
use spc5::tuner::TuneProfile;
use spc5::util::durable;
use spc5::{KernelKind, PlanCache, TenantRegistry};

/// Serializes the suite: the global fault plan and the process-wide
/// degradation log are shared state.
static LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn fresh_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("spc5_durability_{tag}"));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn sample_plan() -> SpmvPlan {
    SpmvEngine::builder(suite::poisson2d(12))
        .kernel(KernelKind::Beta(1, 8))
        .plan()
        .unwrap()
}

fn sample_store() -> RecordStore {
    let mut store = RecordStore::new();
    store.push(PerfRecord {
        matrix: "m".into(),
        kernel: KernelKind::Beta(1, 8),
        avg_nnz_per_block: 3.5,
        threads: 1,
        tile_cols: 0,
        tune: Default::default(),
        gflops: 2.0,
    });
    store
}

/// Removes `<file>.corrupt-*` siblings, returning how many there were.
fn sweep_quarantine(path: &Path) -> usize {
    let dir = path.parent().unwrap();
    let stem = path.file_name().unwrap().to_str().unwrap();
    let mut n = 0;
    for entry in std::fs::read_dir(dir).unwrap() {
        let p = entry.unwrap().path();
        let name = p.file_name().unwrap().to_str().unwrap();
        if name.starts_with(stem) && name.contains(".corrupt-") {
            std::fs::remove_file(&p).unwrap();
            n += 1;
        }
    }
    n
}

/// Flips one bit at every offset of `good` and asserts each mutant is
/// rejected by `load` with the original file quarantined. `load`
/// returns whether the artifact loaded successfully.
fn assert_every_flip_detected(
    label: &str,
    path: &Path,
    good: &[u8],
    load: &dyn Fn(&Path) -> bool,
) {
    for i in 0..good.len() {
        let mut bad = good.to_vec();
        bad[i] ^= 0x01;
        std::fs::write(path, &bad).unwrap();
        let loaded = load(path);
        assert!(
            !loaded,
            "{label}: flip at byte {i} of {} loaded as valid",
            good.len()
        );
        assert!(
            !path.exists(),
            "{label}: flip at byte {i} was not quarantined"
        );
        assert_eq!(
            sweep_quarantine(path),
            1,
            "{label}: flip at byte {i} left no quarantine corpse"
        );
    }
}

#[test]
fn bit_flips_at_every_offset_are_detected_and_quarantined() {
    let _g = lock();
    let dir = fresh_dir("flips");

    // Saved plan.
    let plan = sample_plan();
    let path = dir.join("plan.json");
    plan.save(&path).unwrap();
    let good = std::fs::read(&path).unwrap();
    assert_every_flip_detected("plan", &path, &good, &|p| {
        SpmvPlan::load(p).is_ok()
    });

    // Plan cache.
    let mut cache = PlanCache::new();
    cache.insert(plan.clone());
    let path = dir.join("cache.json");
    cache.save(&path).unwrap();
    let good = std::fs::read(&path).unwrap();
    assert_every_flip_detected("plan-cache", &path, &good, &|p| {
        PlanCache::load(p).is_ok()
    });

    // Record store.
    let path = dir.join("records.json");
    sample_store().save(&path).unwrap();
    let good = std::fs::read(&path).unwrap();
    assert_every_flip_detected("record-store", &path, &good, &|p| {
        RecordStore::load(p).is_ok()
    });

    // Tune profile.
    let profile = TuneProfile::from_json(
        r#"{"version": 1, "machine": "testbox", "entries": []}"#,
    )
    .unwrap();
    let path = dir.join("tune.json");
    profile.save(&path).unwrap();
    let good = std::fs::read(&path).unwrap();
    assert_every_flip_detected("tune-profile", &path, &good, &|p| {
        TuneProfile::load(p).is_ok()
    });

    // Bench report.
    let path = dir.join("bench.json");
    spc5::bench::runner::write_bench_json(&path, "suite", &[]).unwrap();
    let good = std::fs::read(&path).unwrap();
    assert_every_flip_detected("bench-report", &path, &good, &|p| {
        spc5::bench::runner::read_bench_json(p).is_ok()
    });

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn flip_errors_are_typed_and_name_the_artifact() {
    let _g = lock();
    let dir = fresh_dir("typed");
    let path = dir.join("cache.json");
    let mut cache = PlanCache::new();
    cache.insert(sample_plan());
    cache.save(&path).unwrap();
    let mut bytes = std::fs::read(&path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x10;
    std::fs::write(&path, &bytes).unwrap();

    let err = PlanCache::load(&path).expect_err("corruption accepted");
    assert_eq!(err.artifact, PlanCache::ARTIFACT);
    assert_eq!(err.path, path);
    assert!(!err.is_missing());
    let q = err.quarantined_to.clone().expect("quarantined");
    assert!(q.exists());
    let text = err.to_string();
    assert!(
        text.contains("plan-cache") && text.contains("quarantined"),
        "error must name artifact and quarantine: {text}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// The corruption differential: after the plan cache is corrupted on
/// disk, the next cold start degrades (re-plans), persists a repaired
/// cache, and serves a product bit-identical to both the original
/// cached run and a never-cached run.
#[test]
fn cold_start_after_corruption_serves_bit_identical() {
    let _g = lock();
    let dir = fresh_dir("differential");
    let path = dir.join("cache.json");
    let csr = suite::mixed_band_scatter(768, 7);
    let x: Vec<f64> =
        (0..csr.cols).map(|i| (i % 13) as f64 - 6.0).collect();
    let spmv = |e: &SpmvEngine| {
        let mut y = vec![0.0; e.csr().rows];
        e.spmv_into(&x, &mut y);
        y
    };

    // Never-cached baseline.
    let y_fresh = spmv(&SpmvEngine::builder(csr.clone()).build().unwrap());
    // First cached run: plans, persists.
    let e1 = SpmvEngine::builder(csr.clone())
        .plan_cache(&path)
        .build()
        .unwrap();
    let y1 = spmv(&e1);
    assert_eq!(PlanCache::load(&path).unwrap().len(), 1);

    // Corrupt the persisted cache.
    let mut bytes = std::fs::read(&path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x04;
    std::fs::write(&path, &bytes).unwrap();

    // Second cold start: load fails → quarantine → degrade event →
    // re-plan → repaired cache persisted.
    let degraded_before = durable::degrade_count();
    let e2 = SpmvEngine::builder(csr.clone())
        .plan_cache(&path)
        .build()
        .unwrap();
    let y2 = spmv(&e2);
    assert!(
        durable::degrade_count() > degraded_before,
        "corrupt cache must record a degradation"
    );
    assert_eq!(y1, y_fresh, "cached run differs from never-cached run");
    assert_eq!(y2, y_fresh, "post-corruption run differs");
    assert_eq!(e1.plan(), e2.plan(), "re-plan reached a different plan");

    // The repaired cache is valid and serves the third start warm.
    let repaired = PlanCache::load(&path).unwrap();
    assert_eq!(repaired.len(), 1);
    assert!(sweep_quarantine(&path) >= 1, "corpse must be preserved");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn corrupt_tune_profile_degrades_to_baseline() {
    let _g = lock();
    let dir = fresh_dir("tune_degrade");
    let path = dir.join("tune.json");
    std::fs::write(&path, "{ this is not a profile").unwrap();
    let csr = suite::poisson2d(10);

    let degraded_before = durable::degrade_count();
    let e = SpmvEngine::builder(csr.clone())
        .kernel(KernelKind::Beta(1, 8))
        .tune_profile(&path)
        .build()
        .expect("corrupt profile must degrade, not fail the build");
    assert_eq!(e.kernel(), KernelKind::Beta(1, 8));
    assert_eq!(durable::degrade_count(), degraded_before + 1);
    let last = durable::degrade_events().pop().unwrap();
    assert_eq!(last.artifact, TuneProfile::ARTIFACT);
    assert!(last.fallback.contains("baseline"));
    assert!(sweep_quarantine(&path) >= 1);

    // A *missing* profile stays a hard error: a typo'd path must not
    // silently run untuned.
    let missing = dir.join("absent.json");
    assert!(SpmvEngine::builder(csr)
        .kernel(KernelKind::Beta(1, 8))
        .tune_profile(&missing)
        .build()
        .is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn registry_with_corrupt_cache_degrades_and_repairs() {
    let _g = lock();
    let dir = fresh_dir("registry");
    let path = dir.join("cache.json");
    std::fs::write(&path, durable::wrap(b"garbage payload")).unwrap();

    let degraded_before = durable::degrade_count();
    let registry: TenantRegistry =
        TenantRegistry::with_cache(&path).unwrap();
    assert!(durable::degrade_count() > degraded_before);
    assert!(registry
        .degrade_events()
        .iter()
        .any(|e| e.artifact == PlanCache::ARTIFACT));

    // The first registration re-plans and persists a repaired cache.
    let csr = suite::poisson2d(9);
    registry
        .register("tenant", csr, Default::default())
        .unwrap();
    let repaired = PlanCache::load(&path).unwrap();
    assert_eq!(repaired.len(), 1);
    assert!(registry.stats().degraded > degraded_before);
    assert!(sweep_quarantine(&path) >= 1);
    std::fs::remove_dir_all(&dir).ok();
}

/// Satellite regression: a zero-length or whitespace-only store is
/// empty-as-fresh — warn and start empty, never a parse error.
#[test]
fn empty_files_start_fresh_for_stores() {
    let _g = lock();
    let dir = fresh_dir("empty");

    for contents in ["", "   \n\t\n"] {
        let cache_path = dir.join("cache.json");
        std::fs::write(&cache_path, contents).unwrap();
        let cache = PlanCache::load(&cache_path).unwrap();
        assert!(cache.is_empty(), "{contents:?} must load as fresh cache");

        let rec_path = dir.join("records.json");
        std::fs::write(&rec_path, contents).unwrap();
        let store = RecordStore::load(&rec_path).unwrap();
        assert!(
            store.records.is_empty(),
            "{contents:?} must load as fresh store"
        );
    }

    // An explicitly named plan or profile is different: empty means
    // the thing you asked for is not there.
    let plan_path = dir.join("plan.json");
    std::fs::write(&plan_path, "").unwrap();
    assert!(SpmvPlan::load(&plan_path).is_err());
    let tune_path = dir.join("tune.json");
    std::fs::write(&tune_path, "\n").unwrap();
    let err = TuneProfile::load(&tune_path).expect_err("empty profile");
    assert!(err.quarantined_to.is_some());
    sweep_quarantine(&tune_path);
    std::fs::remove_dir_all(&dir).ok();
}

/// Pre-envelope files (bare JSON, written by earlier releases) keep
/// loading — absence of the magic means trusted-legacy.
#[test]
fn legacy_unwrapped_artifacts_still_load() {
    let _g = lock();
    let dir = fresh_dir("legacy");

    let plan = sample_plan();
    let path = dir.join("plan.json");
    std::fs::write(&path, plan.to_json()).unwrap();
    assert_eq!(SpmvPlan::load(&path).unwrap(), plan);

    let mut cache = PlanCache::new();
    cache.insert(plan);
    let path = dir.join("cache.json");
    std::fs::write(&path, cache.to_json()).unwrap();
    assert_eq!(PlanCache::load(&path).unwrap().len(), 1);

    let store = sample_store();
    let path = dir.join("records.json");
    std::fs::write(&path, store.to_json()).unwrap();
    assert_eq!(
        RecordStore::load(&path).unwrap().records.len(),
        store.records.len()
    );

    let path = dir.join("tune.json");
    std::fs::write(
        &path,
        r#"{"version": 1, "machine": "old-box", "entries": []}"#,
    )
    .unwrap();
    assert_eq!(TuneProfile::load(&path).unwrap().machine, "old-box");
    std::fs::remove_dir_all(&dir).ok();
}

/// Torn writes at a schedule of offsets: each leaves either a
/// benign state (empty / complete file) or a detectable one
/// (quarantined on reload) — and the retried save always repairs.
#[test]
fn torn_write_schedule_leaves_recoverable_state() {
    let _g = lock();
    let dir = fresh_dir("torn");
    let path = dir.join("cache.json");
    let mut cache = PlanCache::new();
    cache.insert(sample_plan());

    for at in [0u64, 1, 9, 17, 64, 300, u64::MAX] {
        std::fs::remove_file(&path).ok();
        sweep_quarantine(&path);
        let plan = std::sync::Arc::new(
            spc5::faults::FaultPlan::parse(
                &format!("torn@io_write:at={at},nth=0"),
                0x5eed,
            )
            .unwrap(),
        );
        {
            let _guard = spc5::faults::install_global(plan.clone());
            let err =
                cache.save(&path).expect_err("torn write must error");
            assert!(
                err.to_string().contains("torn"),
                "torn save must say so: {err}"
            );
            assert_eq!(plan.fired(), 1);
        }
        // Reload of the torn file: never a panic, never silently
        // wrong — fresh-empty, fully-written, or quarantined.
        match PlanCache::load(&path) {
            Ok(c) => assert!(
                c.is_empty() || c.len() == 1,
                "torn at {at}: impossible cache state"
            ),
            Err(e) => {
                assert!(
                    e.quarantined_to.is_some(),
                    "torn at {at}: corrupt file not quarantined"
                );
            }
        }
        // The guard is dropped: the retried save is atomic and whole.
        cache.save(&path).unwrap();
        assert_eq!(PlanCache::load(&path).unwrap().len(), 1);
        sweep_quarantine(&path);
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// CI crash-consistency entry point: driven by the env schedule
/// `SPC5_FAULTS=torn@io_write:at=24,nth=0` (fixed seed via
/// `SPC5_FAULTS_SEED`), run alone with `--ignored --exact
/// --test-threads=1` so the nth counter is deterministic.
#[test]
#[ignore = "requires the SPC5_FAULTS torn-write schedule (CI crash-consistency job)"]
fn torn_write_schedule_from_env() {
    let _g = lock();
    assert!(
        spc5::faults::global().is_some(),
        "this test only runs under the CI SPC5_FAULTS schedule"
    );
    let dir = fresh_dir("torn_env");
    let path = dir.join("cache.json");
    let mut cache = PlanCache::new();
    cache.insert(sample_plan());

    // First save hits the env schedule and tears.
    let err = cache.save(&path).expect_err("scheduled torn write");
    assert!(err.to_string().contains("torn"));
    // The torn file is detected at reload (or reads as benign empty
    // when the tear landed at offset zero).
    match PlanCache::load(&path) {
        Ok(c) => assert!(c.is_empty()),
        Err(e) => assert!(e.quarantined_to.is_some()),
    }
    // The schedule is exhausted (nth=0): recovery persists durably.
    cache.save(&path).unwrap();
    assert_eq!(PlanCache::load(&path).unwrap().len(), 1);
    std::fs::remove_dir_all(&dir).ok();
}
