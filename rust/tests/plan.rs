//! Inspector–executor integration tests: `plan()` → JSON →
//! `from_plan()` must reproduce `build()` bit-for-bit, fingerprints
//! must fence plans to their matrix, and the plan cache must serve
//! repeat builds without re-inspection.

use spc5::matrix::suite;
use spc5::predictor::{PerfRecord, RecordStore};
use spc5::{Csr, KernelKind, MatrixFingerprint, PlanCache, SpmvEngine, SpmvPlan};

fn spmv_out(e: &SpmvEngine, x: &[f64]) -> Vec<f64> {
    let mut y = vec![0.0; e.csr().rows];
    e.spmv_into(x, &mut y);
    y
}

/// A store that plants β(4,8) as the high-fill winner so the
/// predictor (and the hybrid panel ranking) has fitted surfaces.
fn planted_store() -> RecordStore {
    let mut store = RecordStore::new();
    for i in 0..16 {
        let avg = 1.0 + i as f64 * 2.0;
        for (kernel, gflops) in [
            (KernelKind::Csr, 1.4),
            (KernelKind::Beta(1, 8), 0.9 + 0.08 * avg),
            (KernelKind::Beta(4, 8), 0.4 + 0.12 * avg),
        ] {
            store.push(PerfRecord {
                matrix: format!("m{i}"),
                kernel,
                avg_nnz_per_block: avg,
                threads: 1,
                tile_cols: 0,
                tune: Default::default(),
                gflops,
            });
        }
    }
    store
}

/// The acceptance matrix: plan → serialize → deserialize → from_plan
/// equals build() exactly, across kernel classes, thread counts and
/// tiling.
#[test]
fn plan_json_from_plan_reproduces_build() {
    let csr = suite::mixed_band_scatter(1_536, 9);
    let x: Vec<f64> = (0..csr.cols).map(|i| (i % 11) as f64 - 5.0).collect();
    let store = planted_store();

    type Cfg = (
        &'static str,
        Box<dyn Fn(Csr) -> spc5::SpmvEngineBuilder<'static, f64>>,
    );
    let configs: Vec<Cfg> = vec![
        ("predictor-driven", Box::new(SpmvEngine::builder)),
        (
            "beta-2x8-par",
            Box::new(|m: Csr| {
                SpmvEngine::builder(m)
                    .kernel(KernelKind::Beta(2, 8))
                    .threads(3)
            }),
        ),
        (
            "beta-test-tiled",
            Box::new(|m: Csr| {
                SpmvEngine::builder(m)
                    .kernel(KernelKind::BetaTest(2, 4))
                    .tile_cols(192)
                    .panel_rows(64)
            }),
        ),
        (
            "hybrid-par",
            Box::new(|m: Csr| {
                SpmvEngine::builder(m)
                    .kernel(KernelKind::Hybrid)
                    .panel_rows(128)
                    .threads(3)
            }),
        ),
        (
            "tiled-kernel",
            Box::new(|m: Csr| {
                SpmvEngine::builder(m)
                    .kernel(KernelKind::Tiled(256))
                    .panel_rows(64)
            }),
        ),
        (
            "csr-par",
            Box::new(|m: Csr| {
                SpmvEngine::builder(m).kernel(KernelKind::Csr).threads(2)
            }),
        ),
        (
            "csr5",
            Box::new(|m: Csr| SpmvEngine::builder(m).kernel(KernelKind::Csr5)),
        ),
    ];

    for (label, make) in &configs {
        // The built engine (inspection + instantiation fused).
        let built = make(csr.clone()).records(&store).build().unwrap();
        // The same decisions through the serialized plan.
        let plan = make(csr.clone()).records(&store).plan().unwrap();
        let text = plan.to_json();
        let parsed = SpmvPlan::from_json(&text).unwrap();
        assert_eq!(plan, parsed, "{label}: JSON round trip");
        let from_plan = SpmvEngine::from_plan(csr.clone(), &parsed).unwrap();

        assert_eq!(built.kernel(), from_plan.kernel(), "{label}: kernel");
        assert_eq!(
            built.tile_cols(),
            from_plan.tile_cols(),
            "{label}: resolved tile width"
        );
        assert_eq!(built.threads(), from_plan.threads(), "{label}: threads");
        assert_eq!(built.plan(), from_plan.plan(), "{label}: stored plan");
        // Bit-for-bit: identical storage ⇒ identical summation order.
        let y_built = spmv_out(&built, &x);
        let y_plan = spmv_out(&from_plan, &x);
        assert_eq!(y_built, y_plan, "{label}: spmv output must be bit-equal");
    }
}

#[test]
fn hybrid_plan_records_schedule_and_reproduces_it() {
    let csr = suite::mixed_band_scatter(2_048, 5);
    let store = planted_store();
    let plan = SpmvEngine::builder(csr.clone())
        .kernel(KernelKind::Hybrid)
        .panel_rows(128)
        .records(&store)
        .plan()
        .unwrap();
    assert!(
        !plan.schedule.is_empty(),
        "hybrid plan must carry the compiled schedule"
    );
    // The schedule covers all rows contiguously.
    assert_eq!(plan.schedule.first().unwrap().row_begin, 0);
    assert_eq!(plan.schedule.last().unwrap().row_end, csr.rows);

    // Instantiation without the record store reproduces the exact
    // segment choices (the decisions live in the plan, not the
    // predictor).
    let e = SpmvEngine::from_plan(csr.clone(), &plan).unwrap();
    let hm = e.hybrid().expect("hybrid storage");
    assert_eq!(hm.n_segments(), plan.schedule.len());
    for (seg, entry) in hm.segments.iter().zip(&plan.schedule) {
        assert_eq!(seg.row_begin, entry.row_begin);
        assert_eq!(seg.row_end, entry.row_end);
        assert_eq!(seg.kernel, entry.kernel);
    }
}

#[test]
fn from_plan_rejects_wrong_matrix() {
    let a = suite::poisson2d(20);
    let b = suite::poisson2d(21); // different dims
    let c = suite::uniform_scatter(a.rows, 5, 7); // same rows, other shape
    let plan = SpmvEngine::builder(a.clone()).plan().unwrap();
    assert_eq!(plan.fingerprint, MatrixFingerprint::of(&a));

    let err = match SpmvEngine::from_plan(b, &plan) {
        Err(e) => e,
        Ok(_) => panic!("plan must refuse a different matrix"),
    };
    assert!(
        err.to_string().contains("fingerprint"),
        "error should name the fingerprint: {err}"
    );
    assert!(SpmvEngine::from_plan(c, &plan).is_err());
    // The right matrix still instantiates.
    SpmvEngine::from_plan(a, &plan).unwrap();
}

#[test]
fn plan_cache_persists_and_serves_repeat_builds() {
    let dir = std::env::temp_dir().join("spc5_plan_cache_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("plans.json");
    std::fs::remove_file(&path).ok();

    let csr = suite::fem_blocked(400, 3, 6, 21);
    let store = planted_store();

    // Miss: plans, stores, saves.
    let e1 = SpmvEngine::builder(csr.clone())
        .records(&store)
        .plan_cache(&path)
        .build()
        .unwrap();
    let cache = PlanCache::load(&path).unwrap();
    assert_eq!(cache.len(), 1, "first build must persist its plan");
    let fp = MatrixFingerprint::of(&csr);
    assert_eq!(cache.find(&fp, 1).unwrap().kernel, e1.kernel());

    // Hit: even with records that would now select differently, the
    // cached plan wins — proof the inspection phase was skipped.
    let mut contrarian = RecordStore::new();
    for i in 0..16 {
        contrarian.push(PerfRecord {
            matrix: format!("m{i}"),
            kernel: KernelKind::Csr,
            avg_nnz_per_block: 1.0 + i as f64,
            threads: 1,
            tile_cols: 0,
            tune: Default::default(),
            gflops: 99.0,
        });
        contrarian.push(PerfRecord {
            matrix: format!("m{i}"),
            kernel: KernelKind::Beta(1, 8),
            avg_nnz_per_block: 1.0 + i as f64,
            threads: 1,
            tile_cols: 0,
            tune: Default::default(),
            gflops: 0.01,
        });
    }
    let e2 = SpmvEngine::builder(csr.clone())
        .records(&contrarian)
        .plan_cache(&path)
        .build()
        .unwrap();
    assert_eq!(e2.kernel(), e1.kernel(), "cache hit must skip selection");
    assert_eq!(e2.plan(), e1.plan());

    // A different thread count is a different cache key.
    let e3 = SpmvEngine::builder(csr.clone())
        .records(&store)
        .threads(3)
        .plan_cache(&path)
        .build()
        .unwrap();
    assert_eq!(e3.threads(), 3);
    let cache = PlanCache::load(&path).unwrap();
    assert_eq!(cache.len(), 2);

    // An incompatible builder config (explicit conflicting kernel)
    // bypasses the cached entry and replans.
    let e4 = SpmvEngine::builder(csr.clone())
        .kernel(KernelKind::Csr)
        .plan_cache(&path)
        .build()
        .unwrap();
    assert_eq!(e4.kernel(), KernelKind::Csr);

    std::fs::remove_file(&path).ok();
}

#[test]
fn plan_outputs_match_engine_outputs_under_reorder() {
    // Reordering is part of the plan: a reordered plan instantiates a
    // reordered engine with caller-index-space products.
    let csr = suite::quantum_clusters(400, 3, 8, 6, 5);
    let x: Vec<f64> = (0..csr.cols).map(|i| (i % 7) as f64 - 3.0).collect();
    let built = SpmvEngine::builder(csr.clone())
        .kernel(KernelKind::Beta(2, 4))
        .reorder(spc5::matrix::ReorderKind::Rcm)
        .build()
        .unwrap();
    let plan = SpmvEngine::builder(csr.clone())
        .kernel(KernelKind::Beta(2, 4))
        .reorder(spc5::matrix::ReorderKind::Rcm)
        .plan()
        .unwrap();
    let restored =
        SpmvEngine::from_plan(csr, &SpmvPlan::from_json(&plan.to_json()).unwrap())
            .unwrap();
    assert_eq!(restored.reorder_kind(), built.reorder_kind());
    assert_eq!(spmv_out(&built, &x), spmv_out(&restored, &x));
}

#[test]
fn f32_plans_roundtrip() {
    let csr32: spc5::Csr<f32> = suite::poisson2d(24).to_precision();
    let built = SpmvEngine::builder(csr32.clone())
        .kernel(KernelKind::Beta(1, 16))
        .build()
        .unwrap();
    let plan = SpmvEngine::builder(csr32.clone())
        .kernel(KernelKind::Beta(1, 16))
        .plan()
        .unwrap();
    let plan = SpmvPlan::from_json(&plan.to_json()).unwrap();
    let restored = SpmvEngine::from_plan(csr32.clone(), &plan).unwrap();
    assert_eq!(restored.kernel(), KernelKind::Beta(1, 16));
    let x: Vec<f32> = (0..csr32.cols).map(|i| (i % 5) as f32 * 0.5).collect();
    let mut y_b = vec![0.0f32; csr32.rows];
    let mut y_p = vec![0.0f32; csr32.rows];
    built.spmv_into(&x, &mut y_b);
    restored.spmv_into(&x, &mut y_p);
    assert_eq!(y_b, y_p, "f32 plan instantiation must be bit-equal");
}

#[test]
fn plan_cache_hosts_many_matrices_and_configs() {
    // Multi-tenant shape: one cache file holding plans for several
    // matrices × several configurations, each retrievable by its own
    // (fingerprint, threads) key after a disk round trip.
    let dir = std::env::temp_dir().join("spc5_multi_tenant_cache_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("plans.json");
    std::fs::remove_file(&path).ok();

    let matrices: Vec<Csr> = vec![
        suite::poisson2d(12),
        suite::fem_blocked(150, 3, 5, 3),
        suite::uniform_scatter(300, 6, 9),
        suite::mixed_band_scatter(512, 11),
    ];
    let mut cache = PlanCache::new();
    for csr in &matrices {
        for threads in [1usize, 3] {
            let plan = SpmvEngine::builder(csr.clone())
                .kernel(KernelKind::Beta(1, 8))
                .threads(threads)
                .plan()
                .unwrap();
            cache.insert(plan);
        }
    }
    assert_eq!(cache.len(), matrices.len() * 2);
    cache.save(&path).unwrap();

    let loaded = PlanCache::load(&path).unwrap();
    assert_eq!(loaded.len(), matrices.len() * 2);
    for csr in &matrices {
        let fp = MatrixFingerprint::of(csr);
        for threads in [1usize, 3] {
            let plan = loaded
                .find(&fp, threads)
                .unwrap_or_else(|| panic!("missing plan ({fp:?}, {threads})"));
            assert_eq!(plan.threads, threads);
            assert_eq!(plan.kernel, KernelKind::Beta(1, 8));
            // The found plan really serves its matrix.
            SpmvEngine::from_plan(csr.clone(), plan).unwrap();
        }
    }
    // Distinct structures never alias to one fingerprint here.
    let fps: std::collections::HashSet<_> = matrices
        .iter()
        .map(|m| MatrixFingerprint::of(m).key())
        .collect();
    assert_eq!(fps.len(), matrices.len());
    std::fs::remove_file(&path).ok();
}

#[test]
fn plan_cache_serves_concurrent_readers() {
    // A registry shares one immutable cache across threads: every
    // reader must find its plan and instantiate from it concurrently.
    let matrices: Vec<Csr> = vec![
        suite::poisson2d(10),
        suite::fem_blocked(120, 3, 5, 5),
        suite::uniform_scatter(240, 5, 2),
    ];
    let mut cache = PlanCache::new();
    for csr in &matrices {
        let plan = SpmvEngine::builder(csr.clone())
            .kernel(KernelKind::Beta(1, 8))
            .plan()
            .unwrap();
        cache.insert(plan);
    }
    let cache = std::sync::Arc::new(cache);
    std::thread::scope(|s| {
        for csr in &matrices {
            for _ in 0..3 {
                let cache = std::sync::Arc::clone(&cache);
                s.spawn(move || {
                    let fp = MatrixFingerprint::of(csr);
                    let plan =
                        cache.find(&fp, 1).expect("plan under concurrency");
                    let e = SpmvEngine::from_plan(csr.clone(), plan).unwrap();
                    let x = vec![1.0; csr.cols];
                    let mut y = vec![0.0; csr.rows];
                    e.spmv_into(&x, &mut y);
                    let mut want = vec![0.0; csr.rows];
                    csr.spmv_ref(&x, &mut want);
                    for (a, b) in y.iter().zip(&want) {
                        assert!(
                            (a - b).abs() <= 1e-10 * b.abs().max(1.0),
                            "concurrent reader produced wrong product"
                        );
                    }
                });
            }
        }
    });
}

#[test]
fn shard_local_plan_refuses_other_shards_submatrix() {
    // The sharded serving tier plans per shard sub-matrix: a plan for
    // shard 0's rows must refuse shard 1's (the fingerprint guard that
    // keeps one shard's schedule off another shard's data).
    let csr = suite::fem_blocked(400, 3, 5, 3);
    let ranges = spc5::parallel::balanced_row_ranges(&csr.rowptr, 2, 8);
    assert_eq!(ranges.len(), 2, "matrix large enough for two shards");
    let shard0 = csr.row_slice(ranges[0].0, ranges[0].1);
    let shard1 = csr.row_slice(ranges[1].0, ranges[1].1);

    let plan0 = SpmvEngine::builder(shard0.clone())
        .kernel(KernelKind::Beta(1, 8))
        .plan()
        .unwrap();
    assert_ne!(
        MatrixFingerprint::of(&shard0),
        MatrixFingerprint::of(&shard1),
        "shard sub-matrices must fingerprint differently"
    );
    // Its own shard instantiates …
    SpmvEngine::from_plan(shard0, &plan0).unwrap();
    // … the other shard is refused.
    let err = match SpmvEngine::from_plan(shard1, &plan0) {
        Err(e) => e,
        Ok(_) => panic!("shard 1 must not accept shard 0's plan"),
    };
    assert!(
        err.to_string().contains("fingerprint"),
        "error should name the fingerprint: {err}"
    );
    // Nor the full matrix.
    assert!(SpmvEngine::from_plan(csr, &plan0).is_err());
}

#[test]
fn malformed_plans_refuse_instantiation() {
    let csr = suite::poisson2d(16);
    let good = SpmvEngine::builder(csr.clone())
        .kernel(KernelKind::Hybrid)
        .panel_rows(64)
        .plan()
        .unwrap()
        .to_json();
    // Corrupt the schedule's row coverage: instantiation re-validates.
    let bad = good.replace("\"row_begin\":0", "\"row_begin\":8");
    let plan = SpmvPlan::from_json(&bad).unwrap();
    assert!(SpmvEngine::from_plan(csr.clone(), &plan).is_err());
    // A hybrid plan stripped of its schedule cannot instantiate.
    let mut no_sched = SpmvPlan::from_json(&good).unwrap();
    no_sched.schedule.clear();
    assert!(SpmvEngine::from_plan(csr, &no_sched).is_err());
}
