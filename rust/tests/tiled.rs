//! Column-tiled execution: dense-oracle differentials across every
//! suite generator class (f64 + f32, sequential + pooled, spmv +
//! spmm), tile-coverage property tests via `validate()`, and
//! tiled-vs-untiled comparisons (bit-exact in the single-tile case,
//! one-ulp-per-partial accumulation tolerance otherwise — a tiled
//! product sums each row's contributions per tile before adding them,
//! so multi-tile results can differ from the flat kernel in the last
//! bits).

use spc5::formats::{
    csr_to_block, BlockSize, HybridConfig, TileCols, TiledHybrid,
    TiledMatrix,
};
use spc5::kernels::KernelKind;
use spc5::matrix::{suite, Csr};
use spc5::util::Rng;
use spc5::SpmvEngine;

/// Dense-oracle product for a matrix small enough to densify, CSR
/// reference otherwise (wide matrices would need rows×cols cells).
fn oracle_f64(csr: &Csr, x: &[f64]) -> Vec<f64> {
    if csr.rows * csr.cols <= 4_000_000 {
        csr.to_dense().matvec(x)
    } else {
        let mut w = vec![0.0; csr.rows];
        csr.spmv_ref(&x.to_vec(), &mut w);
        w
    }
}

/// The matrices the differentials run over: every generator class in
/// the fast subset plus the wide-scatter stress matrix whose `x`
/// working set forces real multi-tile schedules.
fn tiled_test_matrices() -> Vec<(String, Csr)> {
    let mut ms: Vec<(String, Csr)> = suite::test_subset()
        .into_iter()
        .map(|sm| (sm.name.to_string(), sm.csr))
        .collect();
    ms.push(("wide-random".into(), suite::wide_random(512, 120_000, 9)));
    ms
}

#[test]
fn tiled_differential_f64_all_generators() {
    for (name, csr) in tiled_test_matrices() {
        let x: Vec<f64> = (0..csr.cols)
            .map(|i| ((i * 13) % 29) as f64 * 0.25 - 3.0)
            .collect();
        let want = oracle_f64(&csr, &x);
        // A small fixed width forces several tiles on every matrix;
        // Tiled(0) exercises the auto-sized path.
        for kernel in [KernelKind::Tiled(96), KernelKind::Tiled(0)] {
            for threads in [1usize, 3] {
                let engine = SpmvEngine::builder(csr.clone())
                    .kernel(kernel)
                    .panel_rows(64)
                    .threads(threads)
                    .build()
                    .unwrap();
                engine.tiled_hybrid().unwrap().validate().unwrap();
                let mut got = vec![0.0; csr.rows];
                engine.spmv_into(&x, &mut got);
                for i in 0..csr.rows {
                    assert!(
                        (got[i] - want[i]).abs()
                            <= 1e-9 * want[i].abs().max(1.0),
                        "{name} {kernel} t={threads} row {i}: {} vs {}",
                        got[i],
                        want[i]
                    );
                }
            }
        }
    }
}

#[test]
fn tiled_differential_f32_all_generators() {
    for (name, csr) in tiled_test_matrices() {
        let csr32: Csr<f32> = csr.to_precision();
        let x: Vec<f32> = (0..csr32.cols)
            .map(|i| ((i * 7) % 9) as f32 * 0.25 - 1.0)
            .collect();
        // Widened-to-f64 oracle on the truncated values, like the
        // existing f32 differential suite.
        let x64: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        let want64 = if csr32.rows * csr32.cols <= 4_000_000 {
            csr32.to_dense().matvec(&x64)
        } else {
            let mut w = vec![0.0f32; csr32.rows];
            csr32.spmv_ref(&x, &mut w);
            w.iter().map(|&v| v as f64).collect()
        };
        for threads in [1usize, 3] {
            let engine = SpmvEngine::builder(csr32.clone())
                .kernel(KernelKind::Tiled(160))
                .panel_rows(64)
                .threads(threads)
                .build()
                .unwrap();
            engine.tiled_hybrid().unwrap().validate().unwrap();
            let mut got = vec![0.0f32; csr32.rows];
            engine.spmv_into(&x, &mut got);
            for i in 0..csr32.rows {
                let w = want64[i] as f32;
                assert!(
                    (got[i] - w).abs() <= 2e-4 * w.abs().max(1.0),
                    "{name} t={threads} row {i}: {} vs {w}",
                    got[i]
                );
            }
        }
    }
}

#[test]
fn tiled_spmm_differential_f64_and_f32() {
    let csr = suite::mixed_band_scatter(2_048, 17);
    let k = 5usize;
    let mut rng = Rng::new(23);
    let x: Vec<f64> =
        (0..csr.cols * k).map(|_| rng.range_f64(-1.0, 1.0)).collect();
    for threads in [1usize, 4] {
        let engine = SpmvEngine::builder(csr.clone())
            .kernel(KernelKind::Tiled(256))
            .panel_rows(128)
            .threads(threads)
            .build()
            .unwrap();
        let mut y = vec![0.0; csr.rows * k];
        engine.spmm_into(&x, &mut y, k);
        for j in 0..k {
            let xj: Vec<f64> = (0..csr.cols).map(|c| x[c * k + j]).collect();
            let want = oracle_f64(&csr, &xj);
            for r in 0..csr.rows {
                assert!(
                    (y[r * k + j] - want[r]).abs()
                        <= 1e-9 * want[r].abs().max(1.0),
                    "f64 t={threads} j={j} row {r}"
                );
            }
        }
    }
    // f32 multi-RHS through the tiled generic span kernel.
    let csr32: Csr<f32> = csr.to_precision();
    let x32: Vec<f32> = x.iter().map(|&v| v as f32).collect();
    for threads in [1usize, 3] {
        let engine = SpmvEngine::builder(csr32.clone())
            .kernel(KernelKind::Tiled(256))
            .panel_rows(128)
            .threads(threads)
            .build()
            .unwrap();
        let mut y = vec![0.0f32; csr32.rows * k];
        engine.spmm_into(&x32, &mut y, k);
        for j in 0..k {
            let xj: Vec<f32> =
                (0..csr32.cols).map(|c| x32[c * k + j]).collect();
            let mut want = vec![0.0f32; csr32.rows];
            csr32.spmv_ref(&xj, &mut want);
            for r in 0..csr32.rows {
                assert!(
                    (y[r * k + j] - want[r]).abs()
                        <= 2e-4 * want[r].abs().max(1.0),
                    "f32 t={threads} j={j} row {r}"
                );
            }
        }
    }
}

/// Property: for random matrices, block sizes, panel heights and tile
/// widths, the tiled layout validates — spans partition the storage
/// and every block lands in exactly one span — and the product matches
/// the flat kernel.
#[test]
fn tile_coverage_property() {
    let mut rng = Rng::new(0x711E);
    for round in 0..10u64 {
        let rows = 16 + rng.next_below(500);
        let cols = 16 + rng.next_below(900);
        let mut coo = spc5::Coo::new(rows, cols);
        for r in 0..rows {
            if r < cols {
                coo.push(r, r, 1.0 + r as f64);
            }
            let deg = 1 + rng.next_below(5);
            for _ in 0..deg {
                coo.push(r, rng.next_below(cols), rng.range_f64(-2.0, 2.0));
            }
            if r % 4 == 0 {
                let start = rng.next_below(cols.saturating_sub(9).max(1));
                for c in start..(start + 8).min(cols) {
                    coo.push(r, c, 0.25);
                }
            }
        }
        let csr = coo.to_csr().unwrap();
        let x: Vec<f64> = (0..cols).map(|i| ((i * 5) % 11) as f64).collect();
        let mut want = vec![0.0; rows];
        csr.spmv_ref(&x, &mut want);
        for bs in [BlockSize::new(1, 8), BlockSize::new(4, 4)] {
            let bm = csr_to_block(&csr, bs).unwrap();
            for panel_rows in [8usize, 64, 512] {
                for tile_cols in
                    [7usize, 64, 1 + rng.next_below(cols), cols + 100]
                {
                    let tm =
                        TiledMatrix::from_block(&bm, panel_rows, tile_cols)
                            .unwrap();
                    tm.validate().unwrap_or_else(|e| {
                        panic!(
                            "round {round} {bs} panel={panel_rows} \
                             tile={tile_cols}: {e}"
                        )
                    });
                    assert_eq!(tm.nnz(), csr.nnz());
                    let mut got = vec![0.0; rows];
                    tm.spmv(&x, &mut got, false);
                    for i in 0..rows {
                        assert!(
                            (got[i] - want[i]).abs()
                                <= 1e-9 * want[i].abs().max(1.0),
                            "round {round} {bs} panel={panel_rows} \
                             tile={tile_cols} row {i}"
                        );
                    }
                }
            }
        }
        // The tiled hybrid over the same matrix must also validate.
        let cfg =
            HybridConfig { panel_rows: 64, ..HybridConfig::for_scalar::<f64>() };
        let th =
            TiledHybrid::from_csr(&csr, &cfg, None, TileCols::Fixed(96))
                .unwrap();
        th.validate().unwrap();
        assert_eq!(th.nnz(), csr.nnz());
    }
}

/// Tiled-vs-untiled comparison on at least one matrix per generator:
/// with a single tile covering every column the span walk reproduces
/// the flat conversion's block order exactly, so the result must be
/// **bit-identical**; with many tiles the result must agree within the
/// documented accumulation-order tolerance.
#[test]
fn tiled_vs_untiled_per_generator() {
    for (name, csr) in tiled_test_matrices() {
        let bs = BlockSize::new(2, 8);
        let bm = csr_to_block(&csr, bs).unwrap();
        let x: Vec<f64> = (0..csr.cols)
            .map(|i| ((i * 17) % 23) as f64 * 0.5 - 5.0)
            .collect();
        let mut flat = vec![0.0; csr.rows];
        spc5::kernels::spmv_block(&bm, &x, &mut flat, false);

        // One tile ⇒ same accumulation order ⇒ same bits.
        let tm_one =
            TiledMatrix::from_block(&bm, 512, csr.cols.max(1)).unwrap();
        assert_eq!(tm_one.n_tiles, 1, "{name}");
        let mut got_one = vec![0.0; csr.rows];
        tm_one.spmv(&x, &mut got_one, false);
        assert_eq!(got_one, flat, "{name}: single tile must be bit-exact");

        // Many tiles ⇒ per-tile partial sums; tolerance covers the
        // reassociation (documented in the module header).
        let tile = (csr.cols / 7).max(8);
        let tm = TiledMatrix::from_block(&bm, 512, tile).unwrap();
        assert!(tm.n_tiles > 1, "{name}: want a real multi-tile schedule");
        let mut got = vec![0.0; csr.rows];
        tm.spmv(&x, &mut got, false);
        for i in 0..csr.rows {
            assert!(
                (got[i] - flat[i]).abs() <= 1e-9 * flat[i].abs().max(1.0),
                "{name} multi-tile row {i}: {} vs {}",
                got[i],
                flat[i]
            );
        }
    }
}

/// The wide-scatter stress matrix must produce a genuinely tiled
/// schedule under auto sizing (that is what the generator is for), and
/// the engine must agree with the CSR reference on it.
#[test]
fn wide_random_exercises_tiling() {
    let csr = suite::wide_random(768, 200_000, 8);
    // Auto sizing is host-dependent (detected L2); the fixed width
    // guarantees a real multi-tile schedule on any machine.
    let engine = SpmvEngine::builder(csr.clone())
        .kernel(KernelKind::Tiled(8192))
        .build()
        .unwrap();
    assert_eq!(engine.tile_cols(), Some(8192));
    let th = engine.tiled_hybrid().unwrap();
    th.validate().unwrap();
    assert!(
        th.n_spans() > th.n_segments(),
        "wide matrix should split into multiple (panel, tile) spans: \
         {} spans over {} segments",
        th.n_spans(),
        th.n_segments()
    );
    let x: Vec<f64> =
        (0..csr.cols).map(|i| ((i * 3) % 13) as f64 * 0.25).collect();
    let mut want = vec![0.0; csr.rows];
    csr.spmv_ref(&x, &mut want);
    let mut got = vec![0.0; csr.rows];
    engine.spmv_into(&x, &mut got);
    for i in 0..csr.rows {
        assert!(
            (got[i] - want[i]).abs() <= 1e-9 * want[i].abs().max(1.0),
            "row {i}"
        );
    }
}
