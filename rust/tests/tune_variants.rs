//! Differential tests for the machine-level kernel autotuner.
//!
//! The safety contract of the variant table: every tuned variant only
//! changes *when* streams are prefetched and how the block loop is
//! stepped — never the FMA order — so a tuned engine must be
//! **bit-identical** to the baseline build, across precisions
//! (f64/f32), products (spmv/spmm), and runtimes (sequential/pooled).
//! The baseline itself is checked against the dense reference product,
//! so "all variants agree" can never mean "all variants share a bug".

use spc5::matrix::suite;
use spc5::{Csr, KernelKind, SpmvEngine, VARIANT_TABLE};

/// k for the multi-RHS checks: 8 hits the specialized SpMM kernel.
const K: usize = 8;

fn check_variants_f64(kernel: KernelKind, threads: usize) {
    let csr = suite::mixed_band_scatter(1_024, 9);
    let x: Vec<f64> =
        (0..csr.cols).map(|i| ((i * 7) % 11) as f64 * 0.25 - 1.0).collect();
    let xk: Vec<f64> = (0..csr.cols * K)
        .map(|i| ((i * 5) % 13) as f64 * 0.5 - 3.0)
        .collect();

    let base = SpmvEngine::builder(csr.clone())
        .kernel(kernel)
        .panel_rows(64)
        .threads(threads)
        .build()
        .unwrap();
    let mut want_v = vec![0.0; csr.rows];
    base.spmv_into(&x, &mut want_v);
    // Anchor the baseline on the dense oracle before comparing
    // variants against it.
    let mut oracle = vec![0.0; csr.rows];
    csr.spmv_ref(&x, &mut oracle);
    for r in 0..csr.rows {
        assert!(
            (want_v[r] - oracle[r]).abs() <= 1e-9 * oracle[r].abs().max(1.0),
            "f64 {kernel} t={threads} baseline vs oracle, row {r}"
        );
    }
    let mut want_m = vec![0.0; csr.rows * K];
    base.spmm_into(&xk, &mut want_m, K);

    for &t in &VARIANT_TABLE {
        let e = SpmvEngine::builder(csr.clone())
            .kernel(kernel)
            .panel_rows(64)
            .threads(threads)
            .tune(t)
            .build()
            .unwrap();
        assert_eq!(e.plan().tune, Some(t));
        let mut y = vec![0.0; csr.rows];
        e.spmv_into(&x, &mut y);
        assert_eq!(
            y,
            want_v,
            "f64 spmv {kernel} t={threads} variant {} diverged",
            t.label()
        );
        let mut ym = vec![0.0; csr.rows * K];
        e.spmm_into(&xk, &mut ym, K);
        assert_eq!(
            ym,
            want_m,
            "f64 spmm {kernel} t={threads} variant {} diverged",
            t.label()
        );
    }
}

fn check_variants_f32(kernel: KernelKind, threads: usize) {
    let csr: Csr<f32> = suite::mixed_band_scatter(1_024, 9).to_precision();
    let x: Vec<f32> =
        (0..csr.cols).map(|i| ((i * 7) % 11) as f32 * 0.25 - 1.0).collect();
    let xk: Vec<f32> = (0..csr.cols * K)
        .map(|i| ((i * 5) % 13) as f32 * 0.5 - 3.0)
        .collect();

    let base = SpmvEngine::builder(csr.clone())
        .kernel(kernel)
        .panel_rows(64)
        .threads(threads)
        .build()
        .unwrap();
    let mut want_v = vec![0.0f32; csr.rows];
    base.spmv_into(&x, &mut want_v);
    let mut oracle = vec![0.0f32; csr.rows];
    csr.spmv_ref(&x, &mut oracle);
    for r in 0..csr.rows {
        assert!(
            (want_v[r] - oracle[r]).abs()
                <= 2e-4 * oracle[r].abs().max(1.0),
            "f32 {kernel} t={threads} baseline vs oracle, row {r}"
        );
    }
    let mut want_m = vec![0.0f32; csr.rows * K];
    base.spmm_into(&xk, &mut want_m, K);

    for &t in &VARIANT_TABLE {
        let e = SpmvEngine::builder(csr.clone())
            .kernel(kernel)
            .panel_rows(64)
            .threads(threads)
            .tune(t)
            .build()
            .unwrap();
        let mut y = vec![0.0f32; csr.rows];
        e.spmv_into(&x, &mut y);
        assert_eq!(
            y,
            want_v,
            "f32 spmv {kernel} t={threads} variant {} diverged",
            t.label()
        );
        let mut ym = vec![0.0f32; csr.rows * K];
        e.spmm_into(&xk, &mut ym, K);
        assert_eq!(
            ym,
            want_m,
            "f32 spmm {kernel} t={threads} variant {} diverged",
            t.label()
        );
    }
}

#[test]
fn f64_beta_variants_bit_identical_seq() {
    check_variants_f64(KernelKind::Beta(2, 8), 1);
    check_variants_f64(KernelKind::Beta(1, 8), 1);
}

#[test]
fn f64_beta_variants_bit_identical_pooled() {
    check_variants_f64(KernelKind::Beta(2, 8), 3);
}

#[test]
fn f64_hybrid_and_tiled_variants_bit_identical() {
    check_variants_f64(KernelKind::Hybrid, 1);
    check_variants_f64(KernelKind::Tiled(192), 3);
}

#[test]
fn f32_beta_variants_bit_identical_seq_and_pooled() {
    check_variants_f32(KernelKind::Beta(1, 16), 1);
    check_variants_f32(KernelKind::Beta(2, 8), 3);
}

#[test]
fn profile_sweep_plan_spmv_round_trip() {
    // The full offline pipeline: sweep → machine profile file →
    // tune_profile() plan → serialized plan → from_plan engine —
    // with the result still bit-identical to the untuned build.
    let (profile, records) =
        spc5::tuner::sweep(&spc5::tuner::SweepConfig::quick()).unwrap();
    assert!(!profile.entries.is_empty());
    assert!(records.iter().all(|r| r.gflops > 0.0));
    let dir = std::env::temp_dir().join("spc5_tune_variants_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("profile.json");
    profile.save(&path).unwrap();

    let kernel = KernelKind::Beta(2, 8);
    let csr = suite::poisson2d(24);
    let plan = SpmvEngine::builder(csr.clone())
        .kernel(kernel)
        .tune_profile(&path)
        .plan()
        .unwrap();
    // The quick sweep covers b(2,8): the plan must pin its winner.
    assert_eq!(plan.tune, profile.lookup(kernel, 1));
    assert!(plan.tune.is_some());

    // Across the serialization boundary, without the profile file.
    let back = spc5::SpmvPlan::from_json(&plan.to_json()).unwrap();
    assert_eq!(back.tune, plan.tune);
    let tuned = SpmvEngine::from_plan(csr.clone(), &back).unwrap();
    let base = SpmvEngine::builder(csr.clone()).kernel(kernel).build().unwrap();
    let x: Vec<f64> = (0..csr.cols).map(|i| (i % 9) as f64 - 4.0).collect();
    let mut want = vec![0.0; csr.rows];
    base.spmv_into(&x, &mut want);
    let mut y = vec![0.0; csr.rows];
    tuned.spmv_into(&x, &mut y);
    assert_eq!(y, want, "profile-tuned engine diverged from baseline");
    std::fs::remove_file(path).ok();
}

#[test]
fn hybrid_profile_lookup_is_per_segment() {
    // A profile consulted for a hybrid plan resolves each β segment's
    // own block size; the hybrid kernel itself has no profile entry,
    // so the plan-level tune stays unset.
    let (profile, _) =
        spc5::tuner::sweep(&spc5::tuner::SweepConfig::quick()).unwrap();
    let dir = std::env::temp_dir().join("spc5_tune_variants_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("hybrid_profile.json");
    profile.save(&path).unwrap();

    let csr = suite::mixed_band_scatter(2_048, 5);
    let plan = SpmvEngine::builder(csr.clone())
        .kernel(KernelKind::Hybrid)
        .panel_rows(64)
        .tune_profile(&path)
        .plan()
        .unwrap();
    assert_eq!(plan.tune, None);
    // Segments whose β size the sweep covered carry that winner;
    // uncovered sizes and CSR segments stay on the default.
    for s in &plan.schedule {
        if let Some(t) = s.tune {
            assert!(VARIANT_TABLE.contains(&t));
        }
    }
    let e = SpmvEngine::from_plan(csr.clone(), &plan).unwrap();
    let base = SpmvEngine::builder(csr.clone())
        .kernel(KernelKind::Hybrid)
        .panel_rows(64)
        .build()
        .unwrap();
    let x: Vec<f64> = (0..csr.cols).map(|i| (i % 7) as f64 - 3.0).collect();
    let mut want = vec![0.0; csr.rows];
    base.spmv_into(&x, &mut want);
    let mut y = vec![0.0; csr.rows];
    e.spmv_into(&x, &mut y);
    assert_eq!(y, want, "per-segment tuned hybrid diverged");
    std::fs::remove_file(path).ok();
}
