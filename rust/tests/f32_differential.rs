//! Differential tests for the single-precision stack: the generic
//! `spmv_block::<f32>` (and, when the host supports it, the AVX-512
//! `vexpandps` path it dispatches to) is checked against a
//! **widened-to-f64 dense oracle** — the exact double-precision product
//! over the f32-truncated values — with an f32-appropriate relative
//! tolerance, across the suite generators and every β32 size.

use spc5::formats::{csr_to_block, BlockSize};
use spc5::kernels::{scalar, spmv_block};
use spc5::matrix::{suite, Csr};

/// Every size the f32 stack serves: the paper's six plus the 16-lane
/// β32 sizes.
fn f32_sizes() -> Vec<BlockSize> {
    BlockSize::PAPER_SIZES
        .into_iter()
        .chain(BlockSize::F32_WIDE_SIZES)
        .collect()
}

/// Per-row error budget: `coeff · Σ|a_rc·x_c|` models worst-case f32
/// accumulation error (each of the ≤ few hundred terms contributes at
/// most one half-ulp of the running magnitude), plus a small absolute
/// floor for all-cancelling rows.
fn row_tolerances(csr32: &Csr<f32>, x: &[f32], coeff: f64) -> Vec<f64> {
    let mut tol = vec![1e-5f64; csr32.rows];
    for r in 0..csr32.rows {
        let mut l1 = 0.0f64;
        for k in csr32.row_range(r) {
            l1 += (csr32.values[k] as f64 * x[csr32.colidx[k] as usize] as f64)
                .abs();
        }
        tol[r] += coeff * l1;
    }
    tol
}

/// The widened oracle: the f64 dense product over the f32-truncated
/// values. Materialized literally for small matrices; evaluated
/// sparsely (identical sums — skipped terms are exact zeros) when the
/// dense array would be large.
fn widened_oracle(csr32: &Csr<f32>, x: &[f32]) -> Vec<f64> {
    let x64: Vec<f64> = x.iter().map(|&v| v as f64).collect();
    if csr32.rows * csr32.cols <= 1_000_000 {
        return csr32.to_dense().matvec(&x64);
    }
    let csr64: Csr<f64> = csr32.to_precision();
    let mut y = vec![0.0f64; csr64.rows];
    csr64.spmv_ref(&x64, &mut y);
    y
}

fn bench_x(cols: usize) -> Vec<f32> {
    (0..cols).map(|i| ((i * 13) % 29) as f32 * 0.125 - 1.75).collect()
}

#[test]
fn f32_spmv_block_matches_widened_oracle_across_suite() {
    for sm in suite::test_subset() {
        let csr32 = sm.csr.to_precision::<f32>();
        let x = bench_x(csr32.cols);
        let want = widened_oracle(&csr32, &x);
        let tol = row_tolerances(&csr32, &x, 1e-4);
        for bs in f32_sizes() {
            let bm = csr_to_block(&csr32, bs).unwrap();
            let mut y = vec![0.0f32; csr32.rows];
            spmv_block(&bm, &x, &mut y, false);
            for i in 0..csr32.rows {
                assert!(
                    (y[i] as f64 - want[i]).abs() <= tol[i],
                    "{} {bs} row {i}: {} vs {} (tol {})",
                    sm.name,
                    y[i],
                    want[i],
                    tol[i]
                );
            }
        }
    }
}

#[test]
fn f32_test_variant_matches_widened_oracle() {
    // Algorithm 2 at f32: same numbers as Algorithm 1 (the control flow
    // never changes the per-row summation content).
    for sm in suite::test_subset().iter().take(5) {
        let csr32 = sm.csr.to_precision::<f32>();
        let x = bench_x(csr32.cols);
        let want = widened_oracle(&csr32, &x);
        let tol = row_tolerances(&csr32, &x, 1e-4);
        for bs in [BlockSize::new(1, 16), BlockSize::new(1, 8)] {
            let bm = csr_to_block(&csr32, bs).unwrap();
            let mut y = vec![0.0f32; csr32.rows];
            spmv_block(&bm, &x, &mut y, true);
            for i in 0..csr32.rows {
                assert!(
                    (y[i] as f64 - want[i]).abs() <= tol[i],
                    "{} {bs} test row {i}",
                    sm.name
                );
            }
        }
    }
}

#[test]
fn f32_simd_dispatch_agrees_with_portable_kernel() {
    // The dispatched path (AVX-512 when available, else the same scalar
    // kernel) and the explicitly-portable Algorithm 1 must agree to
    // accumulation-order tolerance on every β32 size.
    for sm in suite::test_subset().iter().take(6) {
        let csr32 = sm.csr.to_precision::<f32>();
        let x = bench_x(csr32.cols);
        let tol = row_tolerances(&csr32, &x, 1e-4);
        for bs in BlockSize::F32_WIDE_SIZES {
            let bm = csr_to_block(&csr32, bs).unwrap();
            let mut dispatched = vec![0.0f32; csr32.rows];
            spmv_block(&bm, &x, &mut dispatched, false);
            let mut portable = vec![0.0f32; csr32.rows];
            scalar::spmv_generic(&bm, &x, &mut portable);
            for i in 0..csr32.rows {
                assert!(
                    (dispatched[i] as f64 - portable[i] as f64).abs() <= tol[i],
                    "{} {bs} row {i}: simd {} vs portable {}",
                    sm.name,
                    dispatched[i],
                    portable[i]
                );
            }
        }
    }
}

#[test]
fn f32_wide_conversion_reduces_storage_vs_f64() {
    // The point of the 16-lane stack: halved values + u16 masks beat
    // the f64 format's bytes on every suite class.
    for sm in suite::test_subset().iter().take(6) {
        let csr32 = sm.csr.to_precision::<f32>();
        let b32 = csr_to_block(&csr32, BlockSize::new(1, 16)).unwrap();
        let b64 = csr_to_block(&sm.csr, BlockSize::new(1, 8)).unwrap();
        assert!(
            b32.occupancy_bytes() < b64.occupancy_bytes(),
            "{}: {} vs {}",
            sm.name,
            b32.occupancy_bytes(),
            b64.occupancy_bytes()
        );
    }
}
