//! Cross-language consistency: the Python (`compile.kernels.spmv_block
//! .csr_to_block_desc`) and Rust (`formats::csr_to_block`) conversions
//! must produce the *same* block-row descriptor stream for the same
//! matrix — the contract the AOT artifact path depends on (Rust feeds
//! `values` in an order fixed by its own conversion to an executable
//! whose descriptors were baked by Python's conversion).
//!
//! Skips when `python` (with jax) is not on PATH — the numeric
//! agreement is separately covered by the XLA artifact tests.

use spc5::formats::{csr_to_block, BlockSize};
use spc5::matrix::suite;
use spc5::util::json::Json;

/// Flattens the Rust block matrix to (row, col, mask, offset) block
/// rows — the Python descriptor layout.
fn flatten(
    bm: &spc5::formats::BlockMatrix,
) -> (Vec<u32>, Vec<u32>, Vec<u32>, Vec<u32>) {
    let r = bm.bs.r;
    let (mut rows, mut cols, mut masks, mut offs) =
        (Vec::new(), Vec::new(), Vec::new(), Vec::new());
    let mut off = 0u32;
    for it in 0..bm.intervals() {
        let (a, b) =
            (bm.block_rowptr[it] as usize, bm.block_rowptr[it + 1] as usize);
        for blk in a..b {
            for i in 0..r {
                let mask = bm.block_masks[blk * r + i];
                if mask != 0 {
                    rows.push((it * r + i) as u32);
                    cols.push(bm.block_colidx[blk]);
                    masks.push(mask as u32);
                    offs.push(off);
                    off += mask.count_ones();
                }
            }
        }
    }
    (rows, cols, masks, offs)
}

#[test]
fn python_and_rust_conversions_agree() {
    let n = 12usize;
    let output = std::process::Command::new("python")
        .args(["-m", "compile.dump", "--n", &n.to_string()])
        .current_dir(concat!(env!("CARGO_MANIFEST_DIR"), "/python"))
        .output();
    let output = match output {
        Ok(o) if o.status.success() => o,
        _ => {
            eprintln!("skipping cross-language test (python/jax unavailable)");
            return;
        }
    };
    let text = String::from_utf8(output.stdout).expect("utf8");
    let v = Json::parse(text.trim()).expect("json from compile.dump");
    let get_arr = |k: &str| -> Vec<u32> {
        v.get(k)
            .and_then(|a| a.as_arr())
            .expect(k)
            .iter()
            .map(|x| x.as_f64().unwrap() as u32)
            .collect()
    };

    let csr = suite::poisson2d(n);
    assert_eq!(v.get("nnz").unwrap().as_f64().unwrap() as usize, csr.nnz());
    let bm = csr_to_block(&csr, BlockSize::new(1, 8)).unwrap();
    let (rows, cols, masks, offs) = flatten(&bm);

    // Python arrays are padded to STRIP with mask-0 entries; compare the
    // real prefix.
    let py_masks = get_arr("block_mask");
    let real = rows.len();
    assert!(py_masks.len() >= real);
    assert_eq!(&get_arr("block_row")[..real], &rows[..]);
    assert_eq!(&get_arr("block_col")[..real], &cols[..]);
    assert_eq!(&py_masks[..real], &masks[..]);
    assert_eq!(&get_arr("block_off")[..real], &offs[..]);
    // Padding must be all-zero masks.
    assert!(py_masks[real..].iter().all(|&m| m == 0));
}
