//! Triangular-solve subsystem integration tests: SpTRSV and SymGS
//! dense-oracle differentials (f64/f32 × sequential/level-scheduled ×
//! matrix/unit diagonal), level-schedule coverage properties, the
//! preconditioner iteration-count ordering on an ill-conditioned
//! system, and SolvePlan persistence → `solve_from_plan` replay — all
//! through the public API only.

use spc5::coordinator::{
    cg_solve, pcg_with, solve_from_plan, PrecondKind, Preconditioner,
    SolvePlan, SolverKind, SOLVE_PLAN_VERSION,
};
use spc5::formats::csr_to_block;
use spc5::kernels::sptrsv::{
    sptrsv_lower_block, sptrsv_lower_levels, sptrsv_lower_ref,
    sptrsv_upper_block, sptrsv_upper_levels, sptrsv_upper_ref,
};
use spc5::kernels::symgs::{symgs, symgs_levels};
use spc5::kernels::KernelKind;
use spc5::matrix::{suite, Coo, Csr};
use spc5::parallel::{lower_levels, upper_levels, WorkerPool};
use spc5::util::Rng;
use spc5::{BlockSize, Scalar, SpmvEngine};

/// Rebuilds `a` with a strictly dominant, strictly positive diagonal
/// (`d_r = |a_rr| + Σ|row| + 1` in effect), so every triangular solve
/// and Gauss–Seidel sweep on it is well conditioned.
fn diag_dominant(a: &Csr) -> Csr {
    let n = a.rows;
    let mut rowptr = vec![0u32];
    let mut colidx: Vec<u32> = Vec::new();
    let mut values: Vec<f64> = Vec::new();
    for r in 0..n {
        let mut boost = 1.0;
        for k in a.row_range(r) {
            boost += a.values[k].abs();
        }
        let mut wrote = false;
        for k in a.row_range(r) {
            let c = a.colidx[k] as usize;
            if !wrote && c >= r {
                let orig = if c == r { a.values[k] } else { 0.0 };
                colidx.push(r as u32);
                values.push(boost + orig);
                wrote = true;
                if c == r {
                    continue;
                }
            }
            colidx.push(a.colidx[k]);
            values.push(a.values[k]);
        }
        if !wrote {
            colidx.push(r as u32);
            values.push(boost);
        }
        rowptr.push(colidx.len() as u32);
    }
    Csr::from_raw(n, n, rowptr, colidx, values).unwrap()
}

/// Structurally diverse square fixtures, every diagonal present and
/// dominant.
fn fixtures() -> Vec<(&'static str, Csr)> {
    vec![
        ("poisson2d", suite::poisson2d(18)),
        ("stencil3d", suite::stencil3d(6, 6, 6)),
        ("banded", diag_dominant(&suite::banded(240, 9, 0.35, 7))),
        ("fem", diag_dominant(&suite::fem_blocked(60, 3, 6, 11))),
        ("circuit", diag_dominant(&suite::circuit(220, 4, 6, 5))),
    ]
}

/// Scales a strict triangle so every row sums to at most `rho` in
/// magnitude: a unit-diagonal substitution on the result is
/// contractive, so the dense oracle stays meaningful at f32.
fn damp<T: Scalar>(tri: &Csr<T>, rho: f64) -> Csr<T> {
    let mut t = tri.clone();
    let mut maxrow = 0.0f64;
    for r in 0..t.rows {
        let s: f64 =
            t.row_range(r).map(|k| t.values[k].to_f64().abs()).sum();
        maxrow = maxrow.max(s);
    }
    if maxrow > 0.0 {
        for v in &mut t.values {
            *v = T::from_f64(v.to_f64() * rho / maxrow);
        }
    }
    t
}

/// Dense forward substitution accumulating in **descending** column
/// order — an independent summation order from every kernel under
/// test, so agreement is a genuine differential, not an echo.
fn dense_lower_oracle<T: Scalar>(lower: &Csr<T>, diag: &[T], b: &[T]) -> Vec<T> {
    let n = lower.rows;
    let mut dense = vec![T::ZERO; n * n];
    for r in 0..n {
        for k in lower.row_range(r) {
            dense[r * n + lower.colidx[k] as usize] = lower.values[k];
        }
    }
    let mut x = vec![T::ZERO; n];
    for r in 0..n {
        let mut s = T::ZERO;
        for c in (0..r).rev() {
            s += dense[r * n + c] * x[c];
        }
        x[r] = (b[r] - s) / diag[r];
    }
    x
}

/// Dense backward substitution, also in reversed (here: ascending)
/// accumulation order relative to the kernels.
fn dense_upper_oracle<T: Scalar>(upper: &Csr<T>, diag: &[T], b: &[T]) -> Vec<T> {
    let n = upper.rows;
    let mut dense = vec![T::ZERO; n * n];
    for r in 0..n {
        for k in upper.row_range(r) {
            dense[r * n + upper.colidx[k] as usize] = upper.values[k];
        }
    }
    let mut x = vec![T::ZERO; n];
    for r in (0..n).rev() {
        let mut s = T::ZERO;
        for c in (r + 1..n).rev() {
            s += dense[r * n + c] * x[c];
        }
        x[r] = (b[r] - s) / diag[r];
    }
    x
}

fn assert_rel_close<T: Scalar>(got: &[T], want: &[T], rel: f64, label: &str) {
    assert_eq!(got.len(), want.len(), "{label}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let (g, w) = (g.to_f64(), w.to_f64());
        assert!(
            (g - w).abs() <= rel * w.abs().max(1.0),
            "{label} row {i}: {g} vs {w}"
        );
    }
}

/// Oracle + bit-identity sweep for one lower triangle: CSR reference
/// against the dense oracle, then the block and level-scheduled paths
/// bit-identical to the reference.
fn check_lower_paths<T: Scalar>(
    lower: &Csr<T>,
    diag: &[T],
    b: &[T],
    pool: &WorkerPool,
    sizes: &[BlockSize],
    rel: f64,
    label: &str,
) {
    let n = lower.rows;
    let oracle = dense_lower_oracle(lower, diag, b);
    let mut xref = vec![T::ZERO; n];
    sptrsv_lower_ref(lower, diag, b, &mut xref);
    assert_rel_close(&xref, &oracle, rel, &format!("{label}/lower-ref"));
    for &bs in sizes {
        let bm = csr_to_block(lower, bs).unwrap();
        let mut x = vec![T::ZERO; n];
        sptrsv_lower_block(&bm, diag, b, &mut x);
        assert_eq!(x, xref, "{label}/lower-block {bs}");
    }
    let sched = lower_levels(lower);
    let mut x = vec![T::ZERO; n];
    sptrsv_lower_levels(lower, diag, &sched, pool, b, &mut x);
    assert_eq!(x, xref, "{label}/lower-levels");
}

fn check_upper_paths<T: Scalar>(
    upper: &Csr<T>,
    diag: &[T],
    b: &[T],
    pool: &WorkerPool,
    sizes: &[BlockSize],
    rel: f64,
    label: &str,
) {
    let n = upper.rows;
    let oracle = dense_upper_oracle(upper, diag, b);
    let mut xref = vec![T::ZERO; n];
    sptrsv_upper_ref(upper, diag, b, &mut xref);
    assert_rel_close(&xref, &oracle, rel, &format!("{label}/upper-ref"));
    for &bs in sizes {
        let bm = csr_to_block(upper, bs).unwrap();
        let mut x = vec![T::ZERO; n];
        sptrsv_upper_block(&bm, diag, b, &mut x);
        assert_eq!(x, xref, "{label}/upper-block {bs}");
    }
    let sched = upper_levels(upper);
    let mut x = vec![T::ZERO; n];
    sptrsv_upper_levels(upper, diag, &sched, pool, b, &mut x);
    assert_eq!(x, xref, "{label}/upper-levels");
}

#[test]
fn sptrsv_matches_dense_oracle_f64() {
    let pool = WorkerPool::new(4);
    let sizes = [
        BlockSize { r: 1, c: 8 },
        BlockSize { r: 2, c: 4 },
        BlockSize { r: 4, c: 4 },
    ];
    for (name, csr) in fixtures() {
        let split = csr.triangular_split().unwrap();
        assert!(split.missing_diagonals().is_empty(), "{name}: diag gap");
        let n = split.n();
        let b: Vec<f64> =
            (0..n).map(|i| ((i * 7) % 11) as f64 * 0.5 - 2.5).collect();
        // Non-unit diagonal: the split's own triangles + diagonal.
        let lbl = format!("{name}/split");
        check_lower_paths(
            &split.lower, &split.diag, &b, &pool, &sizes, 1e-10, &lbl,
        );
        check_upper_paths(
            &split.upper, &split.diag, &b, &pool, &sizes, 1e-10, &lbl,
        );
        // Unit diagonal (the ILU-L shape), on contractive triangles so
        // the substitution stays well conditioned.
        let ones = vec![1.0; n];
        let lo = damp(&split.lower, 0.5);
        let up = damp(&split.upper, 0.5);
        let lbl = format!("{name}/unit-diag");
        check_lower_paths(&lo, &ones, &b, &pool, &sizes, 1e-10, &lbl);
        check_upper_paths(&up, &ones, &b, &pool, &sizes, 1e-10, &lbl);
    }
}

#[test]
fn sptrsv_matches_dense_oracle_f32() {
    let pool = WorkerPool::new(4);
    let sizes = [BlockSize { r: 2, c: 8 }, BlockSize { r: 4, c: 16 }];
    for (name, csr64) in fixtures() {
        let csr = csr64.to_precision::<f32>();
        let split = csr.triangular_split().unwrap();
        let n = split.n();
        let b: Vec<f32> =
            (0..n).map(|i| ((i * 5) % 9) as f32 * 0.5 - 2.0).collect();
        let lbl = format!("{name}/f32/split");
        check_lower_paths(
            &split.lower, &split.diag, &b, &pool, &sizes, 2e-3, &lbl,
        );
        check_upper_paths(
            &split.upper, &split.diag, &b, &pool, &sizes, 2e-3, &lbl,
        );
        let ones = vec![1.0f32; n];
        let lo = damp(&split.lower, 0.5);
        let up = damp(&split.upper, 0.5);
        let lbl = format!("{name}/f32/unit-diag");
        check_lower_paths(&lo, &ones, &b, &pool, &sizes, 2e-3, &lbl);
        check_upper_paths(&up, &ones, &b, &pool, &sizes, 2e-3, &lbl);
    }
}

#[test]
fn symgs_level_sweeps_bit_identical_and_reduce_residual() {
    let pool = WorkerPool::new(4);
    for (name, csr) in fixtures() {
        let split = csr.triangular_split().unwrap();
        let n = split.n();
        let b: Vec<f64> = (0..n).map(|i| ((i * 13) % 17) as f64 - 8.0).collect();
        let mut seq = vec![0.0; n];
        symgs(&split, &b, &mut seq, 3);
        let fwd = lower_levels(&split.lower);
        let bwd = upper_levels(&split.upper);
        let mut par = vec![0.0; n];
        symgs_levels(&split, &fwd, &bwd, &pool, &b, &mut par, 3);
        assert_eq!(par, seq, "{name}: level sweeps diverge from sequential");

        // The sweeps actually smooth: residual after 3 symmetric
        // sweeps is below the initial one (x0 = 0 → r0 = b).
        let mut ax = vec![0.0; n];
        csr.spmv_ref(&seq, &mut ax);
        let r2: f64 =
            ax.iter().zip(&b).map(|(a, bb)| (a - bb) * (a - bb)).sum();
        let b2: f64 = b.iter().map(|v| v * v).sum();
        assert!(r2 < b2, "{name}: residual did not shrink ({r2} vs {b2})");

        // f32 mirror of the bit-identity claim.
        let split32 = csr.to_precision::<f32>().triangular_split().unwrap();
        let b32: Vec<f32> = b.iter().map(|&v| v as f32).collect();
        let mut seq32 = vec![0.0f32; n];
        symgs(&split32, &b32, &mut seq32, 2);
        let fwd32 = lower_levels(&split32.lower);
        let bwd32 = upper_levels(&split32.upper);
        let mut par32 = vec![0.0f32; n];
        symgs_levels(&split32, &fwd32, &bwd32, &pool, &b32, &mut par32, 2);
        assert_eq!(par32, seq32, "{name}: f32 level sweeps diverge");
    }
}

#[test]
fn level_schedules_cover_rows_and_respect_dependencies() {
    for (name, csr) in fixtures() {
        let split = csr.triangular_split().unwrap();
        let n = split.n();
        let cases = [
            ("lower", &split.lower, lower_levels(&split.lower)),
            ("upper", &split.upper, upper_levels(&split.upper)),
        ];
        for (which, tri, sched) in &cases {
            assert_eq!(sched.rows.len(), n, "{name}/{which}: row count");
            assert_eq!(
                *sched.level_ptr.last().unwrap() as usize,
                n,
                "{name}/{which}: level_ptr end"
            );
            let mut level_of = vec![usize::MAX; n];
            for l in 0..sched.level_ptr.len() - 1 {
                for k in
                    sched.level_ptr[l] as usize..sched.level_ptr[l + 1] as usize
                {
                    let r = sched.rows[k] as usize;
                    assert_eq!(
                        level_of[r],
                        usize::MAX,
                        "{name}/{which}: row {r} scheduled twice"
                    );
                    level_of[r] = l;
                }
            }
            assert!(
                level_of.iter().all(|&l| l != usize::MAX),
                "{name}/{which}: unscheduled rows"
            );
            // Every dependency (a strict-triangle entry) must be
            // finalized in a strictly earlier level.
            for r in 0..n {
                for k in tri.row_range(r) {
                    let c = tri.colidx[k] as usize;
                    assert!(
                        level_of[c] < level_of[r],
                        "{name}/{which}: row {r} depends on {c} at the \
                         same or later level"
                    );
                }
            }
        }
    }
}

/// Symmetrically scaled 2D Poisson: condition number inflated by
/// ~1e6, the fixture the preconditioner ordering is specified on.
fn scaled_poisson(n: usize) -> Csr {
    let a = suite::poisson2d(n);
    let dim = a.rows;
    let s: Vec<f64> =
        (0..dim).map(|i| 10f64.powi(((i % 7) / 2) as i32)).collect();
    let mut coo = Coo::new(dim, dim);
    for r in 0..dim {
        for k in a.row_range(r) {
            let c = a.colidx[k] as usize;
            coo.push(r, c, s[r] * a.values[k] * s[c]);
        }
    }
    coo.to_csr().unwrap()
}

#[test]
fn preconditioners_cut_iterations_on_illconditioned_poisson() {
    let csr = scaled_poisson(12);
    let dim = csr.rows;
    let engine = SpmvEngine::builder(csr)
        .kernel(KernelKind::Beta(2, 4))
        .build()
        .unwrap();
    let mut rng = Rng::new(0x5EED);
    let b: Vec<f64> = (0..dim).map(|_| rng.range_f64(-1.0, 1.0)).collect();
    let max_iters = 10_000;
    let tol2 = 1e-16;

    let mut x = vec![0.0; dim];
    let cg = cg_solve(&engine, &b, &mut x, max_iters, tol2);
    assert!(cg.converged && !cg.breakdown, "plain cg");

    let run = |kind: PrecondKind| {
        let m = kind.build(engine.csr(), engine.pool()).unwrap();
        let mut x = vec![0.0; dim];
        let rep = pcg_with(&engine, m.as_ref(), &b, &mut x, max_iters, tol2);
        assert!(rep.converged && !rep.breakdown, "{kind}");
        rep.iterations
    };
    let jacobi_it = run(PrecondKind::Jacobi);
    let symgs_it = run(PrecondKind::SymGs { sweeps: 1 });
    let ilu_it = run(PrecondKind::Ilu0);
    assert!(
        jacobi_it < cg.iterations,
        "jacobi {jacobi_it} vs cg {}",
        cg.iterations
    );
    assert!(symgs_it < jacobi_it, "symgs {symgs_it} vs jacobi {jacobi_it}");
    assert!(ilu_it <= symgs_it, "ilu0 {ilu_it} vs symgs {symgs_it}");
}

#[test]
fn solve_plan_persists_and_replays() {
    let csr = suite::poisson2d(16);
    let dim = csr.rows;
    let engine = SpmvEngine::builder(csr.clone())
        .kernel(KernelKind::Beta(1, 8))
        .build()
        .unwrap();
    let kind = PrecondKind::SymGs { sweeps: 2 };
    let m = kind.build(engine.csr(), engine.pool()).unwrap();
    let plan = SolvePlan {
        version: SOLVE_PLAN_VERSION,
        solver: SolverKind::Pcg,
        precond: kind,
        levels: m.level_summary(),
        spmv: engine.plan().clone(),
    };

    let dir = std::env::temp_dir()
        .join(format!("spc5_solve_it_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("solve-plan.json");
    plan.save(&path).unwrap();
    let loaded = SolvePlan::load(&path).unwrap();
    assert_eq!(loaded, plan);

    // Replay: same engine shape, and the rebuilt preconditioner
    // applies bitwise identically to the original.
    let (engine2, m2) = solve_from_plan(csr.clone(), &loaded).unwrap();
    assert_eq!(engine2.kernel(), engine.kernel());
    let r: Vec<f64> = (0..dim).map(|i| ((i * 5) % 9) as f64 - 4.0).collect();
    let mut z1 = vec![0.0; dim];
    m.apply(&r, &mut z1);
    let mut z2 = vec![0.0; dim];
    m2.apply(&r, &mut z2);
    assert_eq!(z1, z2, "replayed preconditioner diverges");

    // The replayed pair solves.
    let b = vec![1.0; dim];
    let mut x = vec![0.0; dim];
    let rep = pcg_with(&engine2, m2.as_ref(), &b, &mut x, 500, 1e-20);
    assert!(rep.converged && !rep.breakdown);

    // A different matrix is refused by fingerprint.
    let err = solve_from_plan(suite::poisson2d(17), &loaded);
    assert!(err.is_err(), "fingerprint mismatch must be refused");

    std::fs::remove_dir_all(&dir).ok();
}
