//! Runtime-reuse regression tests for the persistent worker-pool
//! runtime: (1) an iterative solve must run entirely on pool threads
//! created once — no per-call spawning anywhere on the SpMV hot path —
//! and (2) batched multi-RHS serving must match k independent
//! single-vector products at both precisions.

use spc5::coordinator::{cg_solve, Request, SpmvEngine, SpmvService};
use spc5::kernels::KernelKind;
use spc5::matrix::{suite, Csr};
use spc5::util::Rng;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard};

/// Serializes the tests in this binary: the thread-count watcher must
/// not observe pools spawned by a concurrently running sibling test.
fn serial() -> MutexGuard<'static, ()> {
    static GATE: Mutex<()> = Mutex::new(());
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}

/// Live thread count of this process (Linux: /proc/self/status).
#[cfg(target_os = "linux")]
fn process_threads() -> usize {
    let status =
        std::fs::read_to_string("/proc/self/status").expect("proc status");
    status
        .lines()
        .find_map(|l| l.strip_prefix("Threads:"))
        .expect("Threads: line")
        .trim()
        .parse()
        .expect("thread count")
}

/// Runs `work` while a high-rate watcher samples the process thread
/// count; returns `(baseline_before, peak_during)`. The watcher itself
/// accounts for exactly one thread above the baseline.
#[cfg(target_os = "linux")]
fn thread_peak_during(work: impl FnOnce()) -> (usize, usize) {
    let baseline = process_threads();
    let stop = AtomicBool::new(false);
    let max_seen = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        let watcher = scope.spawn(|| {
            while !stop.load(Ordering::Relaxed) {
                max_seen.fetch_max(process_threads(), Ordering::Relaxed);
                std::thread::sleep(std::time::Duration::from_micros(100));
            }
        });
        work();
        stop.store(true, Ordering::Relaxed);
        watcher.join().unwrap();
    });
    (baseline, max_seen.load(Ordering::Relaxed))
}

/// A CG solve through a parallel engine must (a) reach the reference
/// solution and (b) never raise the process thread count above the
/// persistent pool created at engine build — verified by a high-rate
/// watcher sampling /proc while the solve runs. The old
/// `thread::scope` runtime spawned 4 transient threads per SpMV, i.e.
/// thousands over this solve.
///
/// The solve runs in **two watched windows**: the libtest harness may
/// spawn a sibling test's thread (which immediately parks on
/// `serial()`) at most once during the whole test, so at least one of
/// the windows is free of harness noise — while per-call spawning
/// would pollute *every* window. Asserting on the *minimum* growth
/// keeps the test deterministic without weakening the regression.
#[cfg(target_os = "linux")]
#[test]
fn cg_over_pool_keeps_thread_count_flat() {
    let _guard = serial();
    let csr = suite::poisson2d(20);
    let engine = SpmvEngine::builder(csr.clone())
        .threads(4)
        .kernel(KernelKind::Beta(2, 4))
        .build()
        .unwrap();
    // Warm-up: the pool and its per-worker scratch exist after this.
    let x0 = vec![0.25; csr.cols];
    let mut y0 = vec![0.0; csr.rows];
    engine.spmv_into(&x0, &mut y0);

    let mut rng = Rng::new(41);
    let b: Vec<f64> =
        (0..csr.rows).map(|_| rng.range_f64(-1.0, 1.0)).collect();

    let mut growths = Vec::new();
    for window in 0..2 {
        let mut x = vec![0.0; csr.rows];
        let mut report = None;
        let (baseline, peak) = thread_peak_during(|| {
            report = Some(cg_solve(&engine, &b, &mut x, 2000, 1e-20));
        });
        // `peak` can read 0 if the solve outpaced the first sample.
        growths.push(peak.saturating_sub(baseline));

        let report = report.unwrap();
        assert!(report.converged, "window {window}: {report:?}");
        assert!(
            report.iterations > 30,
            "need a long solve to exercise reuse, got {report:?}"
        );
        // Correctness of the solve itself.
        let mut ax = vec![0.0; csr.rows];
        csr.spmv_ref(&x, &mut ax);
        for i in 0..csr.rows {
            assert!((ax[i] - b[i]).abs() < 1e-7, "window {window} row {i}");
        }
    }

    // Budget per clean window: the watcher thread only. The old
    // per-call runtime spawned 4 transient workers on EVERY SpMV,
    // blowing past this in both windows.
    let min_growth = *growths.iter().min().unwrap();
    assert!(
        min_growth <= 1,
        "thread count rose during CG in every window \
         (growths {growths:?}) — something spawned per call"
    );
}

/// Batched multi-RHS serving must match k independent single-vector
/// products — f64, through the full service path (burst submitted
/// before any recv, so the dispatcher actually coalesces).
#[test]
fn batched_serving_matches_single_vector_oracle_f64() {
    let _guard = serial();
    let csr = suite::quantum_clusters(500, 4, 9, 6, 19);
    let engine = SpmvEngine::builder(csr.clone())
        .kernel(KernelKind::Beta(2, 8))
        .threads(3)
        .build()
        .unwrap();
    let service = SpmvService::start(engine, 8);
    let n = 48u64;
    for id in 0..n {
        let x: Vec<f64> = (0..csr.cols)
            .map(|i| ((i as u64 * 7 + id * 3) % 23) as f64 * 0.125 - 1.0)
            .collect();
        service.submit(Request { id, x }).unwrap();
    }
    for _ in 0..n {
        let resp = service.recv().expect("response");
        let x: Vec<f64> = (0..csr.cols)
            .map(|i| ((i as u64 * 7 + resp.id * 3) % 23) as f64 * 0.125 - 1.0)
            .collect();
        let mut want = vec![0.0; csr.rows];
        csr.spmv_ref(&x, &mut want);
        for i in 0..csr.rows {
            assert!(
                (resp.y[i] - want[i]).abs() <= 1e-9 * want[i].abs().max(1.0),
                "id {} row {i}",
                resp.id
            );
        }
    }
    let stats = service.stats();
    assert_eq!(stats.served, n as usize);
    assert_eq!(service.shutdown(), n as usize);
}

/// Same differential, f32 through the 16-lane stack.
#[test]
fn batched_serving_matches_single_vector_oracle_f32() {
    let _guard = serial();
    let csr32: Csr<f32> = suite::banded(600, 12, 0.5, 9).to_precision();
    let engine = SpmvEngine::builder(csr32.clone())
        .kernel(KernelKind::Beta(2, 16))
        .threads(2)
        .build()
        .unwrap();
    let service = SpmvService::start(engine, 6);
    let n = 30u64;
    for id in 0..n {
        let x: Vec<f32> = (0..csr32.cols)
            .map(|i| ((i as u64 * 5 + id) % 17) as f32 * 0.1 - 0.8)
            .collect();
        service.submit(Request { id, x }).unwrap();
    }
    for _ in 0..n {
        let resp = service.recv().expect("response");
        let x: Vec<f32> = (0..csr32.cols)
            .map(|i| ((i as u64 * 5 + resp.id) % 17) as f32 * 0.1 - 0.8)
            .collect();
        let mut want = vec![0.0f32; csr32.rows];
        csr32.spmv_ref(&x, &mut want);
        for i in 0..csr32.rows {
            assert!(
                (resp.y[i] - want[i]).abs() <= 2e-4 * want[i].abs().max(1.0),
                "id {} row {i}",
                resp.id
            );
        }
    }
    assert_eq!(service.shutdown(), n as usize);
}

/// Direct (no service) engine-level differential: `spmm` against k
/// engine `spmv` calls at both precisions, parallel storage.
#[test]
fn engine_spmm_differential_both_precisions() {
    let _guard = serial();
    let csr = suite::fem_blocked(300, 3, 6, 23);
    let k = 5usize;
    let e64 = SpmvEngine::builder(csr.clone())
        .kernel(KernelKind::Beta(4, 4))
        .threads(3)
        .build()
        .unwrap();
    let x64: Vec<f64> = (0..csr.cols * k)
        .map(|i| ((i * 11) % 31) as f64 * 0.0625 - 0.9)
        .collect();
    let mut y64 = vec![0.0; csr.rows * k];
    e64.spmm_into(&x64, &mut y64, k);
    for j in 0..k {
        let xj: Vec<f64> = (0..csr.cols).map(|c| x64[c * k + j]).collect();
        let mut want = vec![0.0; csr.rows];
        e64.spmv_into(&xj, &mut want);
        for r in 0..csr.rows {
            assert!(
                (y64[r * k + j] - want[r]).abs()
                    <= 1e-9 * want[r].abs().max(1.0),
                "f64 j={j} row {r}"
            );
        }
    }

    let csr32: Csr<f32> = csr.to_precision();
    let e32 = SpmvEngine::builder(csr32.clone())
        .kernel(KernelKind::Beta(1, 16))
        .threads(3)
        .build()
        .unwrap();
    let x32: Vec<f32> = x64.iter().map(|&v| v as f32).collect();
    let mut y32 = vec![0.0f32; csr32.rows * k];
    e32.spmm_into(&x32, &mut y32, k);
    for j in 0..k {
        let xj: Vec<f32> = (0..csr32.cols).map(|c| x32[c * k + j]).collect();
        let mut want = vec![0.0f32; csr32.rows];
        e32.spmv_into(&xj, &mut want);
        for r in 0..csr32.rows {
            assert!(
                (y32[r * k + j] - want[r]).abs()
                    <= 2e-4 * want[r].abs().max(1.0),
                "f32 j={j} row {r}"
            );
        }
    }
}
