//! Serving-tier acceptance tests: the sharded front-end must return
//! **bit-identical** results to a single-engine service, admission
//! control must bound in-flight memory exactly, and no submission may
//! ever be silently dropped.

use spc5::coordinator::{
    QueuePolicy, Request, ServiceError, ShardConfig, ShardedService,
    SpmvService,
};
use spc5::matrix::suite;
use spc5::{Csr, KernelKind, Scalar, SpmvEngine};
use std::collections::BTreeMap;

/// Replaces the values with small integers so every summation order
/// produces the same bits: per-row sums stay far below 2^24, exact in
/// f32 and f64 alike. This makes the spmv-vs-spmm differential
/// deterministic even though batch composition is timing-dependent.
fn integerize<T: Scalar>(csr: &mut Csr<T>) {
    for (i, v) in csr.values.iter_mut().enumerate() {
        *v = T::from_f64(((i % 7) as f64) - 3.0);
    }
}

/// Deterministic small-integer request vector.
fn int_x<T: Scalar>(cols: usize, id: u64) -> Vec<T> {
    (0..cols)
        .map(|i| T::from_f64((((i as u64 + 3 * id) % 9) as f64) - 4.0))
        .collect()
}

/// Runs `n_req` requests through both a single-engine service and a
/// sharded one (same kernel, same integerized matrix), in burst mode
/// (exercising the batched spmm path) or one-at-a-time (the spmv
/// path), and asserts exact equality of every response.
fn differential<T: Scalar>(
    csr: &Csr<T>,
    shards: usize,
    max_batch: usize,
    n_req: u64,
    burst: bool,
) {
    let kernel = KernelKind::Beta(1, 8);
    let engine =
        SpmvEngine::builder(csr.clone()).kernel(kernel).build().unwrap();
    let single = SpmvService::start(engine, max_batch);
    let sharded = ShardedService::start(
        csr.clone(),
        ShardConfig {
            shards,
            kernel: Some(kernel),
            max_batch,
            queue: QueuePolicy::Block { capacity: 256 },
            ..ShardConfig::default()
        },
    )
    .unwrap();
    assert!(
        sharded.n_shards() >= 2,
        "differential needs a real shard split, got {}",
        sharded.n_shards()
    );

    let mut single_y: BTreeMap<u64, Vec<T>> = BTreeMap::new();
    let mut sharded_y: BTreeMap<u64, Vec<T>> = BTreeMap::new();
    if burst {
        for id in 0..n_req {
            single.submit(Request { id, x: int_x(csr.cols, id) }).unwrap();
            sharded.submit(Request { id, x: int_x(csr.cols, id) }).unwrap();
        }
        for _ in 0..n_req {
            let r = single.recv().unwrap();
            single_y.insert(r.id, r.y);
            let r = sharded.recv().unwrap();
            sharded_y.insert(r.id, r.y);
        }
    } else {
        for id in 0..n_req {
            single.submit(Request { id, x: int_x(csr.cols, id) }).unwrap();
            let r = single.recv().unwrap();
            single_y.insert(r.id, r.y);
            sharded.submit(Request { id, x: int_x(csr.cols, id) }).unwrap();
            let r = sharded.recv().unwrap();
            sharded_y.insert(r.id, r.y);
        }
    }

    assert_eq!(single_y.len(), n_req as usize);
    for (id, y) in &single_y {
        let ys = &sharded_y[id];
        assert_eq!(y.len(), ys.len());
        assert!(
            y == ys,
            "request {id}: sharded y differs from single-engine y"
        );
        // Both must also equal the reference product exactly
        // (integer data ⇒ order-independent).
        let x: Vec<T> = int_x(csr.cols, *id);
        let mut want = vec![T::ZERO; csr.rows];
        csr.spmv_ref(&x, &mut want);
        assert!(y == &want, "request {id}: y differs from reference");
    }
    assert_eq!(single.shutdown(), n_req as usize);
    assert_eq!(sharded.shutdown(), n_req as usize);
}

#[test]
fn sharded_bit_identical_f64_spmv_path() {
    let mut csr = suite::fem_blocked(400, 3, 5, 3);
    integerize(&mut csr);
    // max_batch = 1 pins every request to the single-vector kernel.
    differential::<f64>(&csr, 3, 1, 10, false);
}

#[test]
fn sharded_bit_identical_f64_spmm_path() {
    let mut csr = suite::fem_blocked(400, 3, 5, 3);
    integerize(&mut csr);
    // Burst submission with coalescing: the batched spmm path.
    differential::<f64>(&csr, 3, 8, 24, true);
}

#[test]
fn sharded_bit_identical_f32_both_paths() {
    let mut csr64 = suite::fem_blocked(320, 3, 5, 7);
    integerize(&mut csr64);
    let csr: Csr<f32> = csr64.to_precision();
    differential::<f32>(&csr, 2, 1, 8, false);
    differential::<f32>(&csr, 2, 6, 18, true);
}

#[test]
fn sharded_matches_single_engine_real_values() {
    // Real-valued matrix: the aligned shard cut keeps the block
    // structure identical, and with max_batch = 1 both services run
    // the same sequential β kernel over the same blocks — so even
    // floating-point results must agree bit-for-bit.
    let csr = suite::mixed_band_scatter(1_024, 5);
    let kernel = KernelKind::Beta(1, 8);
    let engine =
        SpmvEngine::builder(csr.clone()).kernel(kernel).build().unwrap();
    let single = SpmvService::start(engine, 1);
    let sharded = ShardedService::start(
        csr.clone(),
        ShardConfig {
            shards: 2,
            kernel: Some(kernel),
            max_batch: 1,
            ..ShardConfig::default()
        },
    )
    .unwrap();
    for id in 0..6u64 {
        let x: Vec<f64> = (0..csr.cols)
            .map(|i| ((i as u64 * 7 + id) % 23) as f64 * 0.037 - 0.4)
            .collect();
        single.submit(Request { id, x: x.clone() }).unwrap();
        let ys = single.recv().unwrap().y;
        sharded.submit(Request { id, x }).unwrap();
        let yc = sharded.recv().unwrap().y;
        assert!(ys == yc, "request {id}: real-valued results differ");
    }
    single.shutdown();
    sharded.shutdown();
}

#[test]
fn reject_policy_every_submission_answered_or_overloaded() {
    // The acceptance criterion: with Reject { capacity }, in-flight
    // never exceeds capacity and every submission ends in a Response
    // or an Overloaded error — none vanish.
    let csr = suite::fem_blocked(200, 3, 5, 3);
    let cap = 4usize;
    let service = ShardedService::start(
        csr.clone(),
        ShardConfig {
            shards: 2,
            kernel: Some(KernelKind::Beta(1, 8)),
            max_batch: 4,
            queue: QueuePolicy::Reject { capacity: cap },
            ..ShardConfig::default()
        },
    )
    .unwrap();
    let n_sub = 64u64;
    let mut accepted: Vec<u64> = Vec::new();
    let mut rejected = 0usize;
    let mut received: Vec<u64> = Vec::new();
    for id in 0..n_sub {
        let x = vec![0.5; csr.cols];
        match service.submit(Request { id, x }) {
            Ok(()) => accepted.push(id),
            Err(ServiceError::Overloaded { capacity }) => {
                assert_eq!(capacity, cap);
                rejected += 1;
            }
            Err(e) => panic!("unexpected submit error: {e}"),
        }
        // Drain whenever the window fills so the run makes progress.
        while accepted.len() - received.len() >= cap {
            received.push(service.recv().unwrap().id);
        }
    }
    while received.len() < accepted.len() {
        received.push(service.recv().unwrap().id);
    }
    // Complete accounting: every submission is in exactly one bucket.
    assert_eq!(accepted.len() + rejected, n_sub as usize);
    received.sort_unstable();
    assert_eq!(received, accepted, "every accepted request was answered");
    let stats = service.stats();
    assert!(
        stats.in_flight_high_water <= cap,
        "in-flight {} exceeded capacity {cap}",
        stats.in_flight_high_water
    );
    assert_eq!(stats.rejected, rejected);
    assert_eq!(service.shutdown(), accepted.len());
}

#[test]
fn sharded_block_policy_under_concurrency_never_drops() {
    let csr = suite::fem_blocked(160, 3, 5, 3);
    let service = ShardedService::start(
        csr.clone(),
        ShardConfig {
            shards: 2,
            kernel: Some(KernelKind::Beta(1, 8)),
            max_batch: 4,
            queue: QueuePolicy::Block { capacity: 3 },
            ..ShardConfig::default()
        },
    )
    .unwrap();
    let n = 40usize;
    std::thread::scope(|s| {
        s.spawn(|| {
            for _ in 0..n {
                service.recv().expect("response under backpressure");
            }
        });
        for id in 0..n as u64 {
            let x = vec![1.0; csr.cols];
            service.submit(Request { id, x }).unwrap();
        }
    });
    let stats = service.stats();
    assert_eq!(stats.served, n);
    assert_eq!(stats.rejected, 0);
    assert!(stats.in_flight_high_water <= 3);
    assert_eq!(service.shutdown(), n);
}

#[test]
fn response_latency_components_are_consistent() {
    let csr = suite::fem_blocked(200, 3, 5, 3);
    let service = ShardedService::start(
        csr.clone(),
        ShardConfig {
            shards: 2,
            kernel: Some(KernelKind::Beta(1, 8)),
            ..ShardConfig::default()
        },
    )
    .unwrap();
    for id in 0..10u64 {
        service.submit(Request { id, x: vec![1.0; csr.cols] }).unwrap();
    }
    for _ in 0..10 {
        let r = service.recv().unwrap();
        assert!(r.queue_s >= 0.0 && r.compute_s >= 0.0);
        assert!((r.latency_s - (r.queue_s + r.compute_s)).abs() < 1e-15);
    }
    let rollup = service.stats().rollup();
    assert_eq!(rollup.served, 10);
    assert!(rollup.queue.p50_s <= rollup.queue.p99_s);
    assert!(rollup.compute.p50_s <= rollup.compute.p99_s);
    assert!(rollup.p99_s >= rollup.compute.p50_s);
    service.shutdown();
}
