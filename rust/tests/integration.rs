//! Cross-module integration tests: file I/O → conversion → engine →
//! solver chains, the CLI surface, and the XLA artifact path when
//! artifacts are present.

use spc5::coordinator::{cg_solve, Request, SpmvEngine, SpmvService};
use spc5::kernels::KernelKind;
use spc5::matrix::{market, suite};
use spc5::predictor::{PerfRecord, RecordStore};
use spc5::util::Rng;

/// MatrixMarket file → CSR → engine → SpMV, end to end through the
/// public API only.
#[test]
fn mtx_file_to_engine() {
    let dir = std::env::temp_dir().join("spc5_it");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("m.mtx");

    // Write a generated matrix, read it back, serve it.
    let csr = suite::banded(500, 8, 0.4, 3);
    let mut coo = spc5::matrix::Coo::new(csr.rows, csr.cols);
    for r in 0..csr.rows {
        for k in csr.row_range(r) {
            coo.push(r, csr.colidx[k] as usize, csr.values[k]);
        }
    }
    market::write_file(&path, &coo).unwrap();
    let read_back = market::read_file(&path).unwrap().to_csr().unwrap();
    assert_eq!(csr, read_back);

    let engine = SpmvEngine::builder(read_back.clone()).build().unwrap();
    let x: Vec<f64> = (0..csr.cols).map(|i| (i % 13) as f64 * 0.25).collect();
    let mut y = vec![0.0; csr.rows];
    engine.spmv_into(&x, &mut y);
    let mut want = vec![0.0; csr.rows];
    csr.spmv_ref(&x, &mut want);
    spc5::testkit::assert_close(&y, &want, 1e-9, "mtx->engine");
    std::fs::remove_file(path).ok();
}

/// Records written by a bench-style run must round-trip through the
/// store and drive selection.
#[test]
fn records_to_selection_pipeline() {
    let dir = std::env::temp_dir().join("spc5_it2");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("records.json");

    let mut store = RecordStore::new();
    // Synthetic but realistic records across the avg range.
    for i in 0..20 {
        let avg = 1.0 + i as f64 * 0.4;
        store.push(PerfRecord {
            matrix: format!("train{i}"),
            kernel: KernelKind::Beta(1, 8),
            avg_nnz_per_block: avg,
            threads: 1,
            tile_cols: 0,
            tune: Default::default(),
            gflops: 1.0 + 0.2 * avg,
        });
        store.push(PerfRecord {
            matrix: format!("train{i}"),
            kernel: KernelKind::BetaTest(1, 8),
            avg_nnz_per_block: avg,
            threads: 1,
            tile_cols: 0,
            tune: Default::default(),
            gflops: 1.8 - 0.05 * avg,
        });
    }
    store.save(&path).unwrap();
    let loaded = RecordStore::load(&path).unwrap();
    assert_eq!(loaded.records.len(), 40);

    // High-fill matrix → β(1,8); scattered → test variant.
    let dense = suite::dense(64, 1);
    let kinds = [KernelKind::Beta(1, 8), KernelKind::BetaTest(1, 8)];
    let sel =
        spc5::predictor::select_sequential(&dense, &loaded, &kinds).unwrap();
    assert_eq!(sel.kernel, KernelKind::Beta(1, 8));

    let scatter = suite::uniform_scatter(400, 4, 2);
    let sel2 =
        spc5::predictor::select_sequential(&scatter, &loaded, &kinds).unwrap();
    assert_eq!(sel2.kernel, KernelKind::BetaTest(1, 8));
    std::fs::remove_file(path).ok();
}

/// Engine + CG across kernels and thread counts reach the same answer.
#[test]
fn cg_engine_consistency() {
    let csr = suite::poisson2d(20);
    let mut rng = Rng::new(9);
    let b: Vec<f64> = (0..csr.rows).map(|_| rng.range_f64(-1.0, 1.0)).collect();
    let mut solutions = Vec::new();
    for (kernel, threads) in [
        (KernelKind::Beta(1, 8), 1usize),
        (KernelKind::Beta(2, 8), 1),
        (KernelKind::Beta(4, 4), 3),
        (KernelKind::BetaTest(1, 8), 2),
        // The facade now serves the paper's baselines too.
        (KernelKind::Csr, 1),
        (KernelKind::Csr, 4),
        (KernelKind::Csr5, 1),
    ] {
        let engine = SpmvEngine::builder(csr.clone())
            .threads(threads)
            .kernel(kernel)
            .build()
            .unwrap();
        let mut x = vec![0.0; csr.rows];
        let report = cg_solve(&engine, &b, &mut x, 3000, 1e-22);
        assert!(report.converged, "{kernel} t={threads}: {report:?}");
        solutions.push(x);
    }
    for s in &solutions[1..] {
        spc5::testkit::assert_close(s, &solutions[0], 1e-6, "cg kernels");
    }
}

/// Service under concurrent load returns exact results for every id.
#[test]
fn service_concurrent_correctness() {
    let csr = suite::quantum_clusters(600, 4, 10, 8, 21);
    let engine = SpmvEngine::builder(csr.clone())
        .kernel(KernelKind::Beta(2, 4))
        .build()
        .unwrap();
    let service = SpmvService::start(engine, 5);
    let n = 60u64;
    for id in 0..n {
        let x: Vec<f64> =
            (0..csr.cols).map(|i| ((i as u64 * id) % 17) as f64 * 0.1).collect();
        service.submit(Request { id, x }).unwrap();
    }
    for _ in 0..n {
        let r = service.recv().unwrap();
        let x: Vec<f64> = (0..csr.cols)
            .map(|i| ((i as u64 * r.id) % 17) as f64 * 0.1)
            .collect();
        let mut want = vec![0.0; csr.rows];
        csr.spmv_ref(&x, &mut want);
        spc5::testkit::assert_close(&r.y, &want, 1e-9, "service");
    }
    assert_eq!(service.shutdown(), n as usize);
}

/// The full three-layer path: artifacts (if built) vs native kernels.
#[test]
fn xla_artifact_cg_agrees_with_native() {
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping xla integration (run `make artifacts`)");
        return;
    }
    let mut xla = spc5::runtime::XlaEngine::new(dir).unwrap();
    let w = xla.manifest.workload("cg").unwrap().clone();
    let n = (w.rows as f64).sqrt() as usize;
    let iters = w.iters.unwrap();
    let csr = suite::poisson2d(n);
    xla.validate_matrix("cg", &csr).unwrap();

    let mut rng = Rng::new(0x17E6);
    let b: Vec<f64> = (0..csr.rows).map(|_| rng.range_f64(-1.0, 1.0)).collect();
    let x0 = vec![0.0; csr.rows];
    let out = xla.executor("cg").unwrap().run_f64(&[&csr.values, &b, &x0]).unwrap();

    let engine = SpmvEngine::builder(csr.clone()).build().unwrap();
    let mut x_native = vec![0.0; csr.rows];
    cg_solve(&engine, &b, &mut x_native, iters, 1e-30);
    spc5::testkit::assert_close(&out[0], &x_native, 1e-6, "xla vs native cg");
}

/// Full f32 pipeline through the public API only: cast → engine
/// (predictor default and explicit 16-lane kernel) → service.
#[test]
fn f32_engine_and_service_end_to_end() {
    let csr64 = suite::banded(400, 10, 0.5, 6);
    let csr = csr64.to_precision::<f32>();
    let x: Vec<f32> = (0..csr.cols).map(|i| (i % 11) as f32 * 0.2 - 1.0).collect();
    let mut want = vec![0.0f32; csr.rows];
    csr.spmv_ref(&x, &mut want);

    for kernel in [
        KernelKind::Beta(1, 8),
        KernelKind::Beta(1, 16),
        KernelKind::Beta(4, 16),
        KernelKind::Csr,
        KernelKind::Csr5,
    ] {
        let engine = SpmvEngine::builder(csr.clone())
            .kernel(kernel)
            .threads(2)
            .build()
            .unwrap();
        let mut y = vec![0.0f32; csr.rows];
        engine.spmv_into(&x, &mut y);
        for i in 0..csr.rows {
            assert!(
                (y[i] - want[i]).abs() <= 2e-4 * want[i].abs().max(1.0),
                "{kernel} row {i}"
            );
        }
    }

    let engine = SpmvEngine::builder(csr.clone())
        .kernel(KernelKind::Beta(2, 16))
        .build()
        .unwrap();
    let service = SpmvService::start(engine, 2);
    service.submit(Request { id: 1, x: x.clone() }).unwrap();
    let resp = service.recv().unwrap();
    for i in 0..csr.rows {
        assert!((resp.y[i] - want[i]).abs() <= 2e-4 * want[i].abs().max(1.0));
    }
    assert_eq!(service.shutdown(), 1);
}

/// CLI binary smoke tests through std::process.
#[test]
fn cli_smoke() {
    let bin = env!("CARGO_BIN_EXE_spc5");
    let run = |args: &[&str]| {
        std::process::Command::new(bin)
            .args(args)
            .output()
            .expect("spawn spc5")
    };
    // help
    let out = run(&["help"]);
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("commands:"));
    // kernels
    let out = run(&["kernels"]);
    assert!(out.status.success());
    // stats on one matrix
    let out = run(&["stats", "--matrix", "nd6k"]);
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("nd6k"));
    // spmv with explicit kernel
    let out = run(&["spmv", "--matrix", "ns3Da", "--kernel", "b(2,8)"]);
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("gflops"));
    // baselines now served by the engine
    let out = run(&["spmv", "--matrix", "ns3Da", "--kernel", "csr5"]);
    assert!(out.status.success());
    // f32 stack with a 16-lane kernel
    let out = run(&[
        "spmv", "--matrix", "ns3Da", "--kernel", "b32(1,16)", "--precision",
        "f32",
    ]);
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("precision=f32"));
    // 16-lane kernel at f64 → construction error
    let out = run(&["spmv", "--matrix", "ns3Da", "--kernel", "b(1,16)"]);
    assert!(!out.status.success());
    // unknown matrix → error exit
    let out = run(&["spmv", "--matrix", "definitely-not-a-matrix"]);
    assert!(!out.status.success());
    // bad kernel → error exit
    let out = run(&["spmv", "--matrix", "ns3Da", "--kernel", "b(9,9)"]);
    assert!(!out.status.success());
    // gen + stats on the file
    let dir = std::env::temp_dir().join("spc5_cli");
    std::fs::create_dir_all(&dir).unwrap();
    let mtx = dir.join("gen.mtx");
    let out = run(&["gen", "--class", "banded", "--dim", "400", "--out", mtx.to_str().unwrap()]);
    assert!(out.status.success());
    let out = run(&["stats", "--mtx", mtx.to_str().unwrap()]);
    assert!(out.status.success());
    std::fs::remove_file(mtx).ok();
}
