//! Hybrid row-panel schedule: differential tests against the dense
//! oracle across every suite generator class (f64 + f32, sequential +
//! pooled) and schedule-coverage property tests.

use spc5::formats::{HybridConfig, HybridMatrix, PanelKernel, SegmentStorage};
use spc5::kernels::KernelKind;
use spc5::matrix::{suite, Csr};
use spc5::util::Rng;
use spc5::SpmvEngine;

/// Dense-oracle product for a matrix small enough to densify.
fn oracle_f64(csr: &Csr, x: &[f64]) -> Vec<f64> {
    csr.to_dense().matvec(x)
}

#[test]
fn hybrid_differential_f64_all_generators() {
    for sm in suite::test_subset() {
        let csr = &sm.csr;
        let x: Vec<f64> =
            (0..csr.cols).map(|i| ((i * 13) % 29) as f64 * 0.25 - 3.0).collect();
        let want = if csr.rows * csr.cols <= 4_000_000 {
            oracle_f64(csr, &x)
        } else {
            let mut w = vec![0.0; csr.rows];
            csr.spmv_ref(&x, &mut w);
            w
        };
        for threads in [1usize, 3] {
            let engine = SpmvEngine::builder(csr.clone())
                .kernel(KernelKind::Hybrid)
                .panel_rows(64)
                .threads(threads)
                .build()
                .unwrap();
            let mut got = vec![0.0; csr.rows];
            engine.spmv_into(&x, &mut got);
            for i in 0..csr.rows {
                assert!(
                    (got[i] - want[i]).abs() <= 1e-9 * want[i].abs().max(1.0),
                    "{} t={threads} row {i}: {} vs {}",
                    sm.name,
                    got[i],
                    want[i]
                );
            }
        }
    }
}

#[test]
fn hybrid_differential_f32_all_generators() {
    for sm in suite::test_subset() {
        if sm.csr.rows * sm.csr.cols > 4_000_000 {
            continue; // dense oracle stays small
        }
        let csr32: Csr<f32> = sm.csr.to_precision();
        let x: Vec<f32> =
            (0..csr32.cols).map(|i| ((i * 7) % 9) as f32 * 0.25 - 1.0).collect();
        // Widened-to-f64 dense oracle on the truncated values, like the
        // existing f32 differential suite.
        let x64: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        let want64 = csr32.to_dense().matvec(&x64);
        for threads in [1usize, 3] {
            let engine = SpmvEngine::builder(csr32.clone())
                .kernel(KernelKind::Hybrid)
                .panel_rows(64)
                .threads(threads)
                .build()
                .unwrap();
            let mut got = vec![0.0f32; csr32.rows];
            engine.spmv_into(&x, &mut got);
            for i in 0..csr32.rows {
                let w = want64[i] as f32;
                assert!(
                    (got[i] - w).abs() <= 2e-4 * w.abs().max(1.0),
                    "{} t={threads} row {i}: {} vs {w}",
                    sm.name,
                    got[i]
                );
            }
        }
    }
}

#[test]
fn hybrid_spmm_differential_pooled() {
    let csr = suite::mixed_band_scatter(2_048, 17);
    let k = 5usize;
    let mut rng = Rng::new(23);
    let x: Vec<f64> =
        (0..csr.cols * k).map(|_| rng.range_f64(-1.0, 1.0)).collect();
    for threads in [1usize, 4] {
        let engine = SpmvEngine::builder(csr.clone())
            .kernel(KernelKind::Hybrid)
            .panel_rows(128)
            .threads(threads)
            .build()
            .unwrap();
        let mut y = vec![0.0; csr.rows * k];
        engine.spmm_into(&x, &mut y, k);
        for j in 0..k {
            let xj: Vec<f64> = (0..csr.cols).map(|c| x[c * k + j]).collect();
            let want = oracle_f64(&csr, &xj);
            for r in 0..csr.rows {
                assert!(
                    (y[r * k + j] - want[r]).abs()
                        <= 1e-9 * want[r].abs().max(1.0),
                    "t={threads} j={j} row {r}"
                );
            }
        }
    }
}

/// Property: for random matrices and panel sizes, the compiled
/// schedule covers every row exactly once — no gaps, no overlap — and
/// every stored nonzero is accounted for exactly once.
#[test]
fn schedule_covers_every_row_exactly_once() {
    let mut rng = Rng::new(0x5EED);
    for round in 0..12u64 {
        let rows = 16 + rng.next_below(700);
        let cols = 16 + rng.next_below(700);
        let mut coo = spc5::Coo::new(rows, cols);
        // Mixed structure: runs, diagonal and scatter, density varying
        // by region so panel choices differ.
        for r in 0..rows {
            if r < cols {
                coo.push(r, r, 1.0 + r as f64);
            }
            let deg = 1 + rng.next_below(6);
            for _ in 0..deg {
                let c = rng.next_below(cols);
                coo.push(r, c, rng.range_f64(-2.0, 2.0));
            }
            if r % 3 == 0 {
                let start = rng.next_below(cols.saturating_sub(9).max(1));
                for c in start..(start + 8).min(cols) {
                    coo.push(r, c, 0.5);
                }
            }
        }
        let csr = coo.to_csr().unwrap();
        for panel_rows in [8usize, 24, 128, 1024] {
            let cfg = HybridConfig {
                panel_rows,
                ..HybridConfig::for_scalar::<f64>()
            };
            let hm = HybridMatrix::from_csr(&csr, &cfg, None).unwrap();
            hm.validate().unwrap();

            // Row coverage: each row in exactly one segment.
            let mut covered = vec![0u32; rows];
            for seg in &hm.segments {
                assert!(seg.row_begin < seg.row_end && seg.row_end <= rows);
                assert_eq!(
                    seg.row_begin % panel_rows,
                    0,
                    "round {round}: segment not panel-aligned"
                );
                for c in covered[seg.row_begin..seg.row_end].iter_mut() {
                    *c += 1;
                }
            }
            assert!(
                covered.iter().all(|&c| c == 1),
                "round {round} panel {panel_rows}: row covered != once"
            );

            // nnz conservation, segment by segment.
            let total: usize = hm.segments.iter().map(|s| s.nnz).sum();
            assert_eq!(total, csr.nnz(), "round {round} panel {panel_rows}");

            // Per-segment nnz equals the CSR rows it covers.
            for seg in &hm.segments {
                let want = csr.rowptr[seg.row_end] as usize
                    - csr.rowptr[seg.row_begin] as usize;
                assert_eq!(seg.nnz, want, "round {round}");
                match &seg.storage {
                    SegmentStorage::Block(bm) => assert_eq!(bm.nnz(), want),
                    SegmentStorage::Csr(c) => assert_eq!(c.nnz(), want),
                }
            }
        }
    }
}

#[test]
fn mixed_matrix_schedule_and_speed_sanity() {
    // The constructed mixed matrix must actually split into β and CSR
    // regions (the acceptance-criteria structure, minus the timing).
    let csr = suite::mixed_band_scatter(8_192, 4);
    let engine = SpmvEngine::builder(csr.clone())
        .kernel(KernelKind::Hybrid)
        .panel_rows(512)
        .build()
        .unwrap();
    let hm = engine.hybrid().expect("hybrid storage");
    let used = hm.kernels_used();
    assert!(
        used.iter().any(|k| matches!(k, PanelKernel::Beta(_))),
        "banded half should block: {used:?}"
    );
    assert!(
        used.contains(&PanelKernel::Csr),
        "scattered half should stay CSR: {used:?}"
    );
    // The banded half carries most nnz in β segments.
    let beta_nnz: usize = hm
        .segments
        .iter()
        .filter(|s| matches!(s.kernel, PanelKernel::Beta(_)))
        .map(|s| s.nnz)
        .sum();
    assert!(
        beta_nnz > csr.nnz() / 2,
        "β segments should cover the band: {beta_nnz} of {}",
        csr.nnz()
    );
}

#[test]
fn kernel_kind_parses_hybrid() {
    assert_eq!(KernelKind::parse("hybrid"), Some(KernelKind::Hybrid));
    assert_eq!(KernelKind::parse("HYBRID"), Some(KernelKind::Hybrid));
    assert_eq!(KernelKind::Hybrid.to_string(), "hybrid");
    assert_eq!(
        KernelKind::parse(&KernelKind::Hybrid.to_string()),
        Some(KernelKind::Hybrid)
    );
    assert_eq!(KernelKind::parse("hybridx"), None);
    assert_eq!(KernelKind::Hybrid.block_size(), None);
}
