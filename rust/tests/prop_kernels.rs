//! Property tests: every kernel × every format × randomized matrices
//! must agree with the dense-semantics reference. This is the crate's
//! strongest correctness net — hundreds of seeded random cases covering
//! clustered/scattered rows, empty rows, edge columns, rectangular
//! shapes and all block sizes, through both the sequential and the
//! parallel runtimes.

use spc5::formats::{block_to_csr, csr_to_block, BlockSize};
use spc5::kernels::{scalar, spmv_block, KernelKind, KernelSet};
use spc5::parallel::{ParallelSpmv, ParallelStrategy};
use spc5::testkit::{assert_close, for_each_seed, random_csr, random_vec, MatrixGen};

const CASES: u64 = 60;

#[test]
fn prop_all_kernels_match_reference() {
    for_each_seed(CASES, 0xA001, |seed| {
        let csr = random_csr(seed, MatrixGen::default());
        let x = random_vec(seed, csr.cols);
        let mut want = vec![0.0; csr.rows];
        csr.spmv_ref(&x, &mut want);
        let set = KernelSet::prepare(csr.clone(), &KernelKind::ALL);
        for k in KernelKind::ALL {
            let mut y = vec![0.0; csr.rows];
            set.spmv(k, &x, &mut y);
            assert_close(&y, &want, 1e-9, &format!("{k} seed={seed:#x}"));
        }
    });
}

#[test]
fn prop_conversion_roundtrip_identity() {
    for_each_seed(CASES, 0xA002, |seed| {
        let csr = random_csr(seed, MatrixGen::default());
        for bs in BlockSize::PAPER_SIZES {
            let bm = csr_to_block(&csr, bs).unwrap();
            bm.validate().unwrap();
            let back = block_to_csr(&bm).unwrap();
            assert_eq!(csr, back, "roundtrip {bs} seed={seed:#x}");
        }
    });
}

#[test]
fn prop_mask_popcount_equals_nnz() {
    for_each_seed(CASES, 0xA003, |seed| {
        let csr = random_csr(seed, MatrixGen::default());
        for bs in BlockSize::PAPER_SIZES {
            let bm = csr_to_block(&csr, bs).unwrap();
            let pops: usize = bm
                .block_masks
                .iter()
                .map(|m| m.count_ones() as usize)
                .sum();
            assert_eq!(pops, csr.nnz(), "{bs} seed={seed:#x}");
        }
    });
}

#[test]
fn prop_parallel_equals_sequential() {
    for_each_seed(30, 0xA004, |seed| {
        let csr = random_csr(
            seed,
            MatrixGen { max_rows: 120, max_cols: 90, ..Default::default() },
        );
        let x = random_vec(seed, csr.cols);
        let mut want = vec![0.0; csr.rows];
        csr.spmv_ref(&x, &mut want);
        for bs in [BlockSize::new(1, 8), BlockSize::new(4, 4)] {
            let bm = csr_to_block(&csr, bs).unwrap();
            for threads in [2usize, 3, 8] {
                for strategy in
                    [ParallelStrategy::Shared, ParallelStrategy::NumaSplit]
                {
                    let p =
                        ParallelSpmv::new(bm.clone(), threads, strategy, false);
                    let mut y = vec![0.0; csr.rows];
                    p.spmv(&x, &mut y);
                    assert_close(
                        &y,
                        &want,
                        1e-9,
                        &format!("{bs} t={threads} {strategy:?} seed={seed:#x}"),
                    );
                }
            }
        }
    });
}

#[test]
fn prop_test_variant_equals_plain() {
    for_each_seed(CASES, 0xA005, |seed| {
        // The Algorithm-2 control flow must never change the numbers.
        let csr = random_csr(
            seed,
            MatrixGen { avg_row_nnz: 3, cluster_prob: 0.3, ..Default::default() },
        );
        let x = random_vec(seed, csr.cols);
        for bs in [BlockSize::new(1, 8), BlockSize::new(2, 4)] {
            let bm = csr_to_block(&csr, bs).unwrap();
            let mut y_plain = vec![0.0; csr.rows];
            spmv_block(&bm, &x, &mut y_plain, false);
            let mut y_test = vec![0.0; csr.rows];
            spmv_block(&bm, &x, &mut y_test, true);
            assert_close(
                &y_test,
                &y_plain,
                1e-12,
                &format!("{bs} seed={seed:#x}"),
            );
        }
    });
}

#[test]
fn prop_scalar_generic_any_block_size() {
    // The generic kernel accepts every legal (r, c), not just the six.
    for_each_seed(40, 0xA006, |seed| {
        let csr = random_csr(seed, MatrixGen::default());
        let x = random_vec(seed, csr.cols);
        let mut want = vec![0.0; csr.rows];
        csr.spmv_ref(&x, &mut want);
        let mut rng = spc5::util::Rng::new(seed);
        for _ in 0..4 {
            let r = 1 + rng.next_below(8);
            let c = 1 + rng.next_below(8);
            if r * c > 64 {
                continue;
            }
            let bs = BlockSize::new(r, c);
            let bm = csr_to_block(&csr, bs).unwrap();
            let mut y = vec![0.0; csr.rows];
            scalar::spmv_generic(&bm, &x, &mut y);
            assert_close(&y, &want, 1e-9, &format!("{bs} seed={seed:#x}"));
        }
    });
}

#[test]
fn prop_occupancy_formula_matches_measured() {
    for_each_seed(CASES, 0xA007, |seed| {
        let csr = random_csr(seed, MatrixGen::default());
        for bs in BlockSize::PAPER_SIZES {
            let bm = csr_to_block(&csr, bs).unwrap();
            let analytical = spc5::formats::beta_occupancy_bytes(
                bm.nnz(),
                bm.rows,
                bm.n_blocks(),
                bs,
            );
            let measured = bm.occupancy_bytes();
            assert!(
                measured >= analytical
                    && measured - analytical <= bm.n_blocks() * bs.r,
                "{bs} seed={seed:#x}: analytical {analytical} measured {measured}"
            );
        }
    });
}

#[test]
fn prop_partitioner_covers_disjointly() {
    for_each_seed(CASES, 0xA008, |seed| {
        let csr = random_csr(seed, MatrixGen::default());
        let bm = csr_to_block(&csr, BlockSize::new(2, 8)).unwrap();
        let mut rng = spc5::util::Rng::new(seed);
        let threads = 1 + rng.next_below(9);
        let spans = spc5::parallel::partition_intervals(&bm, threads);
        assert_eq!(spans.len(), threads);
        assert_eq!(spans[0].interval_begin, 0);
        assert_eq!(spans.last().unwrap().interval_end, bm.intervals());
        assert_eq!(spans.last().unwrap().block_end, bm.n_blocks());
        for w in spans.windows(2) {
            assert_eq!(w[0].interval_end, w[1].interval_begin);
            assert_eq!(w[0].block_end, w[1].block_begin);
        }
    });
}

#[test]
fn prop_kernel_kind_display_parse_roundtrip() {
    // Plans serialize kernels as their Display strings, so the
    // spelling must survive `Display → parse` exactly — for every
    // variant, including the f32-wide β sizes, the test variants, and
    // tiled widths (0 spells auto).
    let fixed: Vec<KernelKind> = KernelKind::ALL
        .into_iter()
        .chain(KernelKind::F32_WIDE_KERNELS)
        .chain([
            KernelKind::Hybrid,
            KernelKind::Tiled(0),
            KernelKind::Tiled(1),
            KernelKind::Tiled(4096),
            KernelKind::Tiled(u32::MAX),
            KernelKind::BetaTest(1, 16),
            KernelKind::BetaTest(4, 16),
        ])
        .collect();
    for k in fixed {
        assert_eq!(
            KernelKind::parse(&k.to_string()),
            Some(k),
            "round trip failed for {k}"
        );
    }
    // Randomized sweep over the payload space.
    for_each_seed(200, 0xA009, |seed| {
        let mut rng = spc5::util::Rng::new(seed);
        let r = 1 + rng.next_below(16) as u8;
        let c = 1 + rng.next_below(16) as u8;
        let w = rng.next_u64() as u32;
        for k in [
            KernelKind::Beta(r, c),
            KernelKind::BetaTest(r, c),
            KernelKind::Tiled(w),
        ] {
            let spelled = k.to_string();
            assert_eq!(
                KernelKind::parse(&spelled),
                Some(k),
                "round trip failed for {k} ({spelled}) seed={seed:#x}"
            );
            // Case-insensitivity is part of the contract.
            assert_eq!(
                KernelKind::parse(&spelled.to_ascii_uppercase()),
                Some(k),
                "case-insensitive parse failed for {spelled}"
            );
        }
    });
}
