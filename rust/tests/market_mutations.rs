//! Mutation corpus for the hardened MatrixMarket streaming parser.
//!
//! The contract: `market::read_coo` never panics and never allocates
//! unboundedly, whatever the input — truncations at every offset,
//! byte substitutions at every position, huge / negative / overflowing
//! indices, bogus headers, out-of-range and excess entries, pattern /
//! symmetric edge cases. Every rejection is a line-numbered
//! [`MatrixError::Market`] pointing at the offending line.

use spc5::matrix::{market, MatrixError};

type Coo = spc5::matrix::Coo<f64>;

fn parse(src: &[u8]) -> Result<Coo, MatrixError> {
    market::read_coo::<f64, _>(src)
}

/// Asserts the input fails with `Market { line }` at `want_line` and a
/// message containing `needle`.
fn assert_line(src: &str, want_line: usize, needle: &str) {
    match parse(src.as_bytes()) {
        Err(MatrixError::Market { line, msg }) => {
            assert_eq!(
                line, want_line,
                "wrong line for {src:?} (msg: {msg})"
            );
            assert!(
                msg.contains(needle),
                "message {msg:?} should contain {needle:?}"
            );
        }
        Err(other) => panic!("{src:?}: wrong error type {other}"),
        Ok(_) => panic!("{src:?}: accepted"),
    }
}

const BASES: &[&str] = &[
    "%%MatrixMarket matrix coordinate real general\n\
     % comment line\n\
     3 4 3\n1 1 2.5\n2 3 -1\n3 4 7e-2\n",
    "%%MatrixMarket matrix coordinate real symmetric\n\
     3 3 2\n1 1 4\n3 1 5\n",
    "%%MatrixMarket matrix coordinate real skew-symmetric\n\
     2 2 1\n2 1 3\n",
    "%%MatrixMarket matrix coordinate pattern general\n\
     2 2 2\n1 2\n2 1\n",
    "%%MatrixMarket matrix coordinate integer general\n\
     2 2 1\n2 2 7\n",
    "%%MatrixMarket matrix array real general\n\
     2 2\n1\n0\n0\n4\n",
];

/// Every prefix of every base parses without panicking; a prefix that
/// parses cleanly must describe a complete entry set.
#[test]
fn truncation_at_every_offset_never_panics() {
    for base in BASES {
        let full = parse(base.as_bytes())
            .unwrap_or_else(|e| panic!("base {base:?} must parse: {e}"));
        for cut in 0..base.len() {
            match parse(&base.as_bytes()[..cut]) {
                Err(MatrixError::Market { line, .. }) => {
                    let lines = base[..cut].lines().count().max(1);
                    assert!(
                        line <= lines,
                        "cut {cut}: line {line} past input ({lines})"
                    );
                }
                Err(_) => {}
                Ok(coo) => {
                    // Only a cut that still contains every declared
                    // entry (e.g. dropping a trailing newline or a
                    // final zero of a value literal) may succeed.
                    assert_eq!(
                        (coo.rows, coo.cols),
                        (full.rows, full.cols),
                        "cut {cut} of {base:?} parsed to different dims"
                    );
                    assert_eq!(
                        coo.entries.len(),
                        full.entries.len(),
                        "cut {cut} of {base:?} lost entries silently"
                    );
                }
            }
        }
    }
}

/// Substituting hostile bytes at every position never panics, and
/// failures stay typed.
#[test]
fn byte_substitution_corpus_never_panics() {
    const MUTANTS: &[u8] =
        &[b'0', b'9', b'-', b' ', b'\n', b'%', b'e', b'.', 0xFF, 0x00];
    for base in BASES {
        for pos in 0..base.len() {
            for &m in MUTANTS {
                let mut bytes = base.as_bytes().to_vec();
                if bytes[pos] == m {
                    continue;
                }
                bytes[pos] = m;
                match parse(&bytes) {
                    Ok(coo) => {
                        // Mutants may legitimately parse (a digit
                        // substituted inside a value); the result must
                        // still be structurally sound.
                        assert!(coo
                            .entries
                            .iter()
                            .all(|&(r, c, _)| (r as usize) < coo.rows
                                && (c as usize) < coo.cols));
                    }
                    Err(MatrixError::Market { line, .. }) => {
                        assert!(line >= 1, "line numbers are 1-based");
                    }
                    Err(_) => {}
                }
            }
        }
    }
}

#[test]
fn errors_carry_the_offending_line_number() {
    // Bad header: line 1.
    assert_line("garbage\n1 1 0\n", 1, "not a MatrixMarket");
    assert_line(
        "%%MatrixMarket matrix teapot real general\n1 1 0\n",
        1,
        "unsupported format",
    );
    assert_line(
        "%%MatrixMarket matrix coordinate real general extra\n",
        1,
        "too many header fields",
    );
    assert_line(
        "%%MatrixMarket matrix array pattern general\n2 2\n",
        1,
        "array+pattern",
    );
    assert_line(
        "%%MatrixMarket matrix array real symmetric\n2 2\n",
        1,
        "general symmetry",
    );
    // Size-line problems point at the size line.
    let h = "%%MatrixMarket matrix coordinate real general\n";
    assert_line(&format!("{h}2 2\n"), 2, "needs 3 numbers");
    assert_line(&format!("{h}% pad\n% pad\n2 2 9\n"), 4, "exceeds rows*cols");
    assert_line(
        &format!("{h}5000000000 1 1\n1 1 1\n"),
        2,
        "exceeds the supported maximum",
    );
    assert_line(&format!("{h}-2 2 1\n1 1 1\n"), 2, "bad row count");
    assert_line(
        &format!("{h}2 2 99999999999999999999\n"),
        2,
        "bad entry count",
    );
    // Entry problems point at the entry's own physical line.
    assert_line(&format!("{h}2 2 1\n% pad\n3 1 1\n"), 4, "out of range");
    assert_line(&format!("{h}2 2 1\n0 1 1\n"), 3, "out of range");
    assert_line(&format!("{h}2 2 1\n-1 1 1\n"), 3, "bad row index");
    assert_line(&format!("{h}2 2 1\n1 1\n"), 3, "entry needs 3 fields");
    assert_line(&format!("{h}2 2 1\n1 1 1 1\n"), 3, "more than 3 fields");
    assert_line(&format!("{h}2 2 1\n1 1 nan\n"), 3, "non-finite");
    assert_line(&format!("{h}2 2 1\n1 1 1e999\n"), 3, "non-finite");
    assert_line(&format!("{h}2 2 1\n1 1 bogus\n"), 3, "bad value");
    assert_line(
        &format!("{h}2 2 1\n1 1 1\n2 2 1\n"),
        4,
        "more entries than the declared 1",
    );
    assert_line(&format!("{h}2 2 2\n1 1 1\n"), 3, "entry count mismatch");
    // Pattern entries take exactly 2 fields.
    let p = "%%MatrixMarket matrix coordinate pattern general\n";
    assert_line(&format!("{p}2 2 1\n1 2 1\n"), 3, "more than 2 fields");
    // Symmetric storage must be lower-triangular.
    let s = "%%MatrixMarket matrix coordinate real symmetric\n";
    assert_line(&format!("{s}3 3 1\n1 3 5\n"), 3, "lower triangle");
    let k = "%%MatrixMarket matrix coordinate real skew-symmetric\n";
    assert_line(&format!("{k}2 2 1\n1 1 3\n"), 3, "strict lower");
    // Non-UTF-8 bytes are a typed error at their line.
    let mut evil = format!("{h}2 2 1\n1 1 ").into_bytes();
    evil.extend_from_slice(&[0xFF, 0xFE, b'\n']);
    match parse(&evil) {
        Err(MatrixError::Market { line, msg }) => {
            assert_eq!(line, 3);
            assert!(msg.contains("UTF-8"));
        }
        other => panic!("non-UTF-8 accepted: {other:?}"),
    }
}

/// Header claims alone cannot force large allocations: a matrix
/// declaring two-billion-square dimensions with five entries parses in
/// bounded memory.
#[test]
fn huge_declared_dimensions_do_not_preallocate() {
    let src = "%%MatrixMarket matrix coordinate real general\n\
               2000000000 2000000000 5\n\
               1 1 1\n\
               2000000000 2000000000 2\n\
               1 2000000000 3\n\
               2000000000 1 4\n\
               1000000000 1000000000 5\n";
    let coo = parse(src.as_bytes()).unwrap();
    assert_eq!((coo.rows, coo.cols), (2_000_000_000, 2_000_000_000));
    assert_eq!(coo.entries.len(), 5);
    // The capacity reflects the real entry count, not the dense size
    // the header implies.
    assert!(coo.entries.capacity() < 1 << 21);

    // Same for the array format: the claim is bounded before any
    // value arrives, and the (empty) body fails the count check
    // rather than allocating rows*cols slots.
    let dense = "%%MatrixMarket matrix array real general\n\
                 4294967295 4294967295\n";
    match parse(dense.as_bytes()) {
        Err(MatrixError::Market { msg, .. }) => {
            assert!(msg.contains("values"), "unexpected: {msg}")
        }
        other => panic!("dense bomb accepted: {other:?}"),
    }

    // An index outside the u32 storage range is rejected even when
    // the declared dimensions are legal.
    let src = "%%MatrixMarket matrix coordinate real general\n\
               4294967295 4294967295 1\n\
               18446744073709551615 1 1\n";
    match parse(src.as_bytes()) {
        Err(MatrixError::Market { line, msg }) => {
            assert_eq!(line, 3);
            assert!(msg.contains("exceeds the supported maximum"));
        }
        other => panic!("overflowing index accepted: {other:?}"),
    }
}

/// A single over-long line is rejected at the cap, not buffered whole.
#[test]
fn line_length_is_capped() {
    let mut src = String::from(
        "%%MatrixMarket matrix coordinate real general\n%",
    );
    src.push_str(&"x".repeat(market::MAX_LINE + 16));
    src.push_str("\n2 2 1\n1 1 1\n");
    match parse(src.as_bytes()) {
        Err(MatrixError::Market { line, msg }) => {
            assert_eq!(line, 2);
            assert!(msg.contains("longer than"));
        }
        other => panic!("oversized line accepted: {other:?}"),
    }
}

/// Duplicate coordinates are legal MatrixMarket (summed downstream by
/// `to_csr`); the parser keeps both.
#[test]
fn duplicate_entries_are_kept_for_downstream_summing() {
    let src = "%%MatrixMarket matrix coordinate real general\n\
               2 2 2\n1 1 1.5\n1 1 2.5\n";
    let coo = parse(src.as_bytes()).unwrap();
    assert_eq!(coo.entries.len(), 2);
    let csr = coo.to_csr().unwrap();
    assert_eq!(csr.to_dense().get(0, 0), 4.0);
}

/// Whitespace-tolerant forms still parse: blank lines between
/// entries, CR-free tabs, and a missing final newline.
#[test]
fn benign_formatting_variants_parse() {
    let src = "%%MatrixMarket matrix coordinate real general\n\
               \n% note\n\n2 2 2\n\n1 1 1\n\n2 2 2";
    let coo = parse(src.as_bytes()).unwrap();
    assert_eq!(coo.entries.len(), 2);
    let src = "%%MatrixMarket matrix coordinate real general\n\
               2\t2\t1\n1\t1\t1\n";
    assert_eq!(parse(src.as_bytes()).unwrap().entries.len(), 1);
}
