//! Chaos differential suite: deterministic fault injection driven
//! over the sharded and tenant serving tiers. The invariants pinned
//! here are the fault-tolerance contract:
//!
//! - every **delivered** `Response.y` is bit-identical to the
//!   single-engine oracle, faults or not;
//! - every **accepted** submit terminates in exactly one of
//!   {`Response`, typed error} — no lost ids, no duplicates, no
//!   hangs;
//! - restart-budget exhaustion actually poisons (the circuit breaker
//!   escalates instead of thrashing);
//! - the worker pool stays usable after an injected worker panic.
//!
//! Fault schedules are seeded [`FaultPlan`]s, so every run replays
//! the same faults; tests that install a process-global plan
//! serialize on [`GLOBAL`] so they cannot leak injections into each
//! other's services.

use spc5::coordinator::{
    QueuePolicy, RecvError, Request, RestartBudget, ServiceError,
    ShardConfig, ShardHealth, ShardedService, SpmvService, TenantConfig,
    TenantRegistry,
};
use spc5::faults::{self, Action, FaultPlan, FaultRule, SiteKind};
use spc5::matrix::suite;
use spc5::parallel::WorkerPool;
use spc5::{Csr, KernelKind, Scalar, SpmvEngine};
use std::collections::BTreeSet;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

/// Serializes every test in this binary: two tests share the
/// process-global fault plan (`install_global`), and a global plan
/// would otherwise inject into services started by a concurrently
/// running test.
static GLOBAL: Mutex<()> = Mutex::new(());

fn serial() -> MutexGuard<'static, ()> {
    GLOBAL.lock().unwrap_or_else(|e| e.into_inner())
}

/// Small-integer values: per-row sums stay exact in every summation
/// order, so "bit-identical" is meaningful across shard splits and
/// batch compositions (same trick as `tests/serving.rs`).
fn integerize<T: Scalar>(csr: &mut Csr<T>) {
    for (i, v) in csr.values.iter_mut().enumerate() {
        *v = T::from_f64(((i % 7) as f64) - 3.0);
    }
}

fn int_x<T: Scalar>(cols: usize, id: u64) -> Vec<T> {
    (0..cols)
        .map(|i| T::from_f64((((i as u64 + 3 * id) % 9) as f64) - 4.0))
        .collect()
}

fn reference<T: Scalar>(csr: &Csr<T>, id: u64) -> Vec<T> {
    let x: Vec<T> = int_x(csr.cols, id);
    let mut want = vec![T::ZERO; csr.rows];
    csr.spmv_ref(&x, &mut want);
    want
}

/// The acceptance scenario: a kernel-task panic is injected into one
/// shard mid-stream. The faulted generation fails with a typed error
/// (never a hang, never a silent drop), the shard restarts from its
/// retained plan, subsequent submits succeed, and everything
/// delivered — before and after the fault — is bit-identical to the
/// single-engine oracle.
#[test]
fn shard_panic_midstream_recovers_bit_identical() {
    let _g = serial();
    let mut csr = suite::fem_blocked(400, 3, 5, 3);
    integerize(&mut csr);
    let kernel = KernelKind::Beta(1, 8);

    // One-at-a-time submission ⇒ one batch per request per shard, so
    // "second matching hit on shard 1" is exactly request id 1.
    let plan = Arc::new(FaultPlan::new(
        vec![FaultRule::new(SiteKind::Compute, Action::Panic).shard(1).nth(1)],
        0xC4A05,
    ));
    let sharded = ShardedService::start(
        csr.clone(),
        ShardConfig {
            shards: 3,
            kernel: Some(kernel),
            max_batch: 4,
            queue: QueuePolicy::Block { capacity: 64 },
            faults: Some(Arc::clone(&plan)),
            ..ShardConfig::default()
        },
    )
    .unwrap();
    assert_eq!(sharded.n_shards(), 3);
    let engine =
        SpmvEngine::builder(csr.clone()).kernel(kernel).build().unwrap();
    let oracle = SpmvService::start(engine, 4);

    let mut failed: Vec<(u64, RecvError)> = Vec::new();
    for id in 0..12u64 {
        sharded.submit(Request { id, x: int_x(csr.cols, id) }).unwrap();
        oracle.submit(Request { id, x: int_x(csr.cols, id) }).unwrap();
        let want = oracle.recv().unwrap();
        assert_eq!(want.id, id);
        match sharded.recv() {
            Ok(got) => {
                assert_eq!(got.id, id);
                assert!(
                    got.y == want.y,
                    "request {id}: sharded y differs from oracle"
                );
                assert!(got.y == reference(&csr, id));
            }
            Err(e) => failed.push((id, e)),
        }
    }

    // Exactly the faulted request failed, with full attribution.
    assert_eq!(
        failed,
        vec![(1, RecvError::Failed { shard: 1, generation: 0 })]
    );
    assert_eq!(plan.fired(), 1);
    assert_eq!(sharded.restarts(), 1);
    assert!(!sharded.poisoned());
    let health = sharded.health();
    assert!(health.iter().all(|h| h.health == ShardHealth::Up));
    assert_eq!(health[1].restarts, 1);
    assert_eq!(health[1].generation, 1);
    assert!(
        health[1].last_fault.as_deref().unwrap_or("").contains("panic"),
        "restarted shard should remember its last fault"
    );
    assert_eq!(health[0].restarts, 0);
    assert_eq!(sharded.shutdown(), 11);
    oracle.shutdown();
}

/// Burst traffic under seeded probabilistic panics: every accepted
/// submit terminates in exactly one of {response, typed error} — the
/// delivered ids are unique, the failed count covers the rest, and
/// nothing hangs. Delivered payloads stay bit-identical to the
/// reference product throughout the restarts.
#[test]
fn accepted_submits_terminate_exactly_once() {
    let _g = serial();
    let mut csr = suite::fem_blocked(600, 3, 5, 3);
    integerize(&mut csr);
    // One guaranteed kill (the 6th batch on shard 2) plus a seeded
    // probabilistic sprinkle capped at two more — at least one
    // restart always happens, never more than three.
    let plan = Arc::new(FaultPlan::new(
        vec![
            FaultRule::new(SiteKind::Compute, Action::Panic).shard(2).nth(5),
            FaultRule::new(SiteKind::Compute, Action::Panic)
                .prob(0.25)
                .times(2),
        ],
        0xD1CE,
    ));
    let sharded = ShardedService::start(
        csr.clone(),
        ShardConfig {
            shards: 3,
            kernel: Some(KernelKind::Beta(1, 8)),
            max_batch: 4,
            queue: QueuePolicy::Block { capacity: 16 },
            faults: Some(Arc::clone(&plan)),
            ..ShardConfig::default()
        },
    )
    .unwrap();
    assert_eq!(sharded.n_shards(), 3);

    let mut accepted = 0usize;
    let mut refused = 0usize;
    let mut failures = 0usize;
    let mut delivered: BTreeSet<u64> = BTreeSet::new();
    let mut outstanding = 0usize;
    for id in 0..48u64 {
        match sharded.submit(Request { id, x: int_x(csr.cols, id) }) {
            Ok(()) => {
                accepted += 1;
                outstanding += 1;
            }
            Err(ServiceError::ShardFailed { .. }) => refused += 1,
            Err(e) => panic!("unexpected submit error: {e}"),
        }
        if outstanding >= 8 {
            while outstanding > 0 {
                match sharded.recv() {
                    Ok(r) => {
                        assert!(
                            delivered.insert(r.id),
                            "duplicate response id {}",
                            r.id
                        );
                        assert!(r.y == reference(&csr, r.id));
                    }
                    Err(RecvError::Failed { .. }) => failures += 1,
                    Err(e) => panic!("unexpected recv error: {e}"),
                }
                outstanding -= 1;
            }
        }
    }
    while outstanding > 0 {
        match sharded.recv() {
            Ok(r) => {
                assert!(delivered.insert(r.id));
                assert!(r.y == reference(&csr, r.id));
            }
            Err(RecvError::Failed { .. }) => failures += 1,
            Err(e) => panic!("unexpected recv error: {e}"),
        }
        outstanding -= 1;
    }

    // Exactly-one-fate accounting: every accepted id is either
    // delivered once or aborted with a typed error, and ids that were
    // refused at submit never produce anything.
    assert_eq!(delivered.len() + failures, accepted);
    assert_eq!(accepted + refused, 48);
    assert_eq!(plan.fired() as usize, sharded.restarts());
    assert!(
        sharded.restarts() >= 1,
        "seeded schedule should fire at least once (fired={})",
        plan.fired()
    );
    assert!(!sharded.poisoned(), "budget is generous; no escalation");
    assert_eq!(sharded.shutdown(), delivered.len());
}

/// The circuit breaker: a shard that keeps dying exhausts its restart
/// budget and the service escalates to poison — typed errors on every
/// path, all shards reported `Poisoned`, no restart thrash.
#[test]
fn restart_budget_exhaustion_poisons_everything() {
    let _g = serial();
    let mut csr = suite::fem_blocked(300, 3, 5, 3);
    integerize(&mut csr);
    let plan = Arc::new(FaultPlan::new(
        vec![FaultRule::new(SiteKind::Compute, Action::Panic).shard(0)],
        7,
    ));
    let sharded = ShardedService::start(
        csr.clone(),
        ShardConfig {
            shards: 2,
            kernel: Some(KernelKind::Beta(1, 8)),
            max_batch: 2,
            queue: QueuePolicy::Block { capacity: 8 },
            budget: RestartBudget {
                max_restarts: 1,
                window: Duration::from_secs(3600),
            },
            faults: Some(Arc::clone(&plan)),
            ..ShardConfig::default()
        },
    )
    .unwrap();

    // First fault: within budget, restarted, typed abort.
    sharded.submit(Request { id: 0, x: int_x(csr.cols, 0) }).unwrap();
    assert_eq!(
        sharded.recv().unwrap_err(),
        RecvError::Failed { shard: 0, generation: 0 }
    );
    assert_eq!(sharded.restarts(), 1);
    assert!(!sharded.poisoned());

    // Second fault: budget exhausted ⇒ poison, not another restart.
    sharded.submit(Request { id: 1, x: int_x(csr.cols, 1) }).unwrap();
    assert_eq!(
        sharded.recv().unwrap_err(),
        RecvError::Failed { shard: 0, generation: 1 }
    );
    assert!(sharded.poisoned());
    assert_eq!(sharded.restarts(), 1);
    assert!(sharded
        .health()
        .iter()
        .all(|h| h.health == ShardHealth::Poisoned));
    assert!(matches!(
        sharded.submit(Request { id: 2, x: int_x(csr.cols, 2) }),
        Err(ServiceError::ShardFailed { shard: 0, .. })
    ));
    assert!(matches!(
        sharded.recv_timeout(Duration::from_millis(50)),
        Err(RecvError::Failed { shard: 0, .. })
    ));
    assert_eq!(sharded.shutdown(), 0);
}

/// The `worker` site: an injected panic inside a pool task is caught
/// and re-raised on the caller exactly like a real kernel panic — and
/// the pool keeps serving afterwards.
#[test]
fn pool_stays_usable_after_injected_worker_panic() {
    let _g = serial();
    let plan = Arc::new(FaultPlan::new(
        vec![FaultRule::new(SiteKind::Worker, Action::Panic).times(1)],
        11,
    ));
    let _guard = faults::install_global(Arc::clone(&plan));
    let pool = WorkerPool::new(4);

    let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        pool.run(|_ctx| {});
    }))
    .expect_err("the injected worker panic must reach the caller");
    let msg = err
        .downcast_ref::<String>()
        .cloned()
        .unwrap_or_else(|| "<non-string payload>".into());
    assert!(
        msg.contains("spc5 injected fault"),
        "unexpected panic payload: {msg}"
    );
    assert_eq!(plan.fired(), 1);

    // The pool survives: all four workers run on subsequent epochs.
    let hits = AtomicUsize::new(0);
    for _ in 0..3 {
        pool.run(|_ctx| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
    }
    assert_eq!(hits.load(Ordering::Relaxed), 12);
}

/// Delay faults (queue stalls, recv delays) slow the pipeline down
/// but corrupt nothing: no restarts, every response bit-identical.
#[test]
fn delay_faults_do_not_corrupt_results() {
    let _g = serial();
    let mut csr = suite::fem_blocked(300, 3, 5, 3);
    integerize(&mut csr);
    let plan = Arc::new(FaultPlan::new(
        vec![
            FaultRule::new(
                SiteKind::Submit,
                Action::Delay(Duration::from_millis(1)),
            )
            .every(3),
            FaultRule::new(
                SiteKind::Recv,
                Action::Delay(Duration::from_millis(1)),
            )
            .every(2),
            FaultRule::new(
                SiteKind::Compute,
                Action::Delay(Duration::from_millis(2)),
            )
            .every(5),
        ],
        21,
    ));
    let sharded = ShardedService::start(
        csr.clone(),
        ShardConfig {
            shards: 2,
            kernel: Some(KernelKind::Beta(1, 8)),
            max_batch: 4,
            queue: QueuePolicy::Block { capacity: 16 },
            faults: Some(Arc::clone(&plan)),
            ..ShardConfig::default()
        },
    )
    .unwrap();
    for id in 0..15u64 {
        sharded.submit(Request { id, x: int_x(csr.cols, id) }).unwrap();
        let r = sharded.recv().unwrap();
        assert_eq!(r.id, id);
        assert!(r.y == reference(&csr, id));
    }
    assert!(plan.fired() > 0, "delay schedule should have fired");
    assert_eq!(sharded.restarts(), 0);
    assert_eq!(sharded.shutdown(), 15);
}

/// Tenant-level degradation: a sharded tenant takes a shard panic,
/// the registry's typed errors surface it, `submit_with_retry` rides
/// through the restart, and the per-tenant health report shows the
/// recovery.
#[test]
fn tenant_retry_rides_through_shard_restart() {
    let _g = serial();
    // Global plan: the tenant registry builds its sharded services
    // with no per-service plan, so they inherit this one. `nth(0)` on
    // shard 0 ⇒ the first batch dispatched there dies, once.
    let plan = Arc::new(FaultPlan::new(
        vec![FaultRule::new(SiteKind::Compute, Action::Panic)
            .shard(0)
            .nth(0)],
        3,
    ));
    let _guard = faults::install_global(Arc::clone(&plan));

    let registry: TenantRegistry = TenantRegistry::new();
    let mut csr = suite::fem_blocked(400, 3, 5, 3);
    integerize(&mut csr);
    let fp = registry
        .register(
            "chaotic",
            csr.clone(),
            TenantConfig {
                shards: 2,
                kernel: Some(KernelKind::Beta(1, 8)),
                ..TenantConfig::default()
            },
        )
        .unwrap();

    // The first request hits the injected panic: typed abort.
    registry
        .submit_with_retry(
            &fp,
            Request { id: 0, x: int_x(csr.cols, 0) },
            3,
            Duration::from_millis(2),
        )
        .unwrap();
    assert_eq!(
        registry.recv(&fp).unwrap_err(),
        RecvError::Failed { shard: 0, generation: 0 }
    );
    assert_eq!(plan.fired(), 1);

    // Retry path after the supervised restart: served, bit-identical.
    registry
        .submit_with_retry(
            &fp,
            Request { id: 1, x: int_x(csr.cols, 1) },
            3,
            Duration::from_millis(2),
        )
        .unwrap();
    let r = registry.recv(&fp).unwrap();
    assert_eq!(r.id, 1);
    assert!(r.y == reference(&csr, 1));

    let health = registry.tenant_health(&fp).unwrap();
    assert_eq!(health.len(), 2);
    assert!(health.iter().all(|h| h.health == ShardHealth::Up));
    assert_eq!(health[0].restarts, 1);
    assert_eq!(health[1].restarts, 0);
    assert_eq!(registry.deregister(&fp), Some(1));
}
