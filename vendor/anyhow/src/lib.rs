//! Minimal, dependency-free stand-in for the `anyhow` crate.
//!
//! The container builds fully offline, so the crate vendors the small
//! subset of the `anyhow` API it actually uses: the opaque [`Error`]
//! type, the [`Result`] alias, and the `anyhow!` / `bail!` / `ensure!`
//! macros. Semantics match the real crate for this subset: any
//! `std::error::Error` converts into [`Error`] through `?`, and
//! [`Error`] itself deliberately does **not** implement
//! `std::error::Error` (that is what makes the blanket `From` legal).

use std::fmt;

/// An opaque, message-carrying error.
pub struct Error {
    msg: String,
}

impl Error {
    /// Wraps any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

/// `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Builds an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)+) => {
        $crate::Error::msg(format!($($arg)+))
    };
}

/// Returns early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::anyhow!($($arg)+))
    };
}

/// Returns early with a formatted [`Error`] when the condition fails.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    #[test]
    fn macros_and_conversions() {
        fn inner(fail: bool) -> crate::Result<u32> {
            crate::ensure!(!fail, "failed with code {}", 7);
            Ok(1)
        }
        assert_eq!(inner(false).unwrap(), 1);
        assert_eq!(inner(true).unwrap_err().to_string(), "failed with code 7");

        fn io_err() -> crate::Result<()> {
            Err(std::io::Error::new(std::io::ErrorKind::Other, "disk on fire"))?;
            Ok(())
        }
        assert!(io_err().unwrap_err().to_string().contains("disk on fire"));
    }
}
