"""Pallas kernel vs oracle — the core L1 correctness signal.

Hypothesis sweeps shapes, densities, block sizes and dtypes; every case
is checked against two independent references (CSR numpy oracle and the
descriptor-based jnp oracle).
"""

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import random_csr, spmv_csr_ref, spmv_desc_ref
from compile.kernels.spmv_block import STRIP, csr_to_block_desc, spmv

jax.config.update("jax_enable_x64", True)


def run_case(rows, cols, density, r, c, dtype, seed):
    rng = np.random.default_rng(seed)
    rowptr, colidx, values, _ = random_csr(rng, rows, cols, density, dtype)
    desc = csr_to_block_desc(
        rowptr, colidx, values, rows, cols, r=r, c=c, dtype=dtype
    )
    x = rng.uniform(-1.0, 1.0, cols).astype(dtype)

    want = spmv_csr_ref(rowptr, colidx, values, x)
    got_ref = np.asarray(spmv_desc_ref(desc, x))
    got_pallas = np.asarray(spmv(desc, jax.numpy.asarray(x)))

    tol = 1e-10 if dtype == np.float64 else 2e-5
    np.testing.assert_allclose(got_ref, want, rtol=tol, atol=tol)
    np.testing.assert_allclose(got_pallas, want, rtol=tol, atol=tol)


@settings(max_examples=25, deadline=None)
@given(
    rows=st.integers(1, 48),
    cols=st.integers(1, 48),
    density=st.floats(0.02, 0.6),
    rc=st.sampled_from([(1, 8), (2, 4), (2, 8), (4, 4), (4, 8), (8, 4)]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_oracle_f64(rows, cols, density, rc, seed):
    run_case(rows, cols, density, rc[0], rc[1], np.float64, seed)


@settings(max_examples=10, deadline=None)
@given(
    rows=st.integers(1, 32),
    cols=st.integers(1, 32),
    density=st.floats(0.05, 0.5),
    rc=st.sampled_from([(1, 8), (4, 4)]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_oracle_f32(rows, cols, density, rc, seed):
    run_case(rows, cols, density, rc[0], rc[1], np.float32, seed)


def test_empty_matrix():
    rowptr = np.zeros(9, dtype=np.int32)
    desc = csr_to_block_desc(
        rowptr, np.zeros(0, np.int32), np.zeros(0), 8, 8
    )
    x = np.ones(8)
    y = np.asarray(spmv(desc, jax.numpy.asarray(x)))
    np.testing.assert_array_equal(y, np.zeros(8))


def test_single_entry_last_column():
    # Block anchored at the final column: clamped gathers must not leak.
    rows, cols = 3, 17
    rowptr = np.array([0, 0, 1, 1], dtype=np.int32)
    colidx = np.array([16], dtype=np.int32)
    values = np.array([2.5])
    desc = csr_to_block_desc(rowptr, colidx, values, rows, cols)
    x = np.arange(cols, dtype=np.float64)
    y = np.asarray(spmv(desc, jax.numpy.asarray(x)))
    np.testing.assert_allclose(y, [0.0, 2.5 * 16, 0.0])


def test_identity_large():
    # Bigger than several strips: exercises the cross-strip accumulate.
    n = 3 * STRIP + 37
    rowptr = np.arange(n + 1, dtype=np.int32)
    colidx = np.arange(n, dtype=np.int32)
    values = np.ones(n)
    desc = csr_to_block_desc(rowptr, colidx, values, n, n)
    assert desc.n_padded >= 3 * STRIP
    x = np.linspace(-1, 1, n)
    y = np.asarray(spmv(desc, jax.numpy.asarray(x)))
    np.testing.assert_allclose(y, x, rtol=1e-12)


def test_values_are_not_padded():
    rng = np.random.default_rng(7)
    rowptr, colidx, values, _ = random_csr(rng, 30, 30, 0.2)
    desc = csr_to_block_desc(rowptr, colidx, values, 30, 30)
    # The defining property of the paper's format: stored values ==
    # nonzeros exactly, no zero padding.
    assert desc.nnz == len(values)
    assert np.count_nonzero(desc.values) == len(values)


def test_mask_popcounts_sum_to_nnz():
    rng = np.random.default_rng(8)
    rowptr, colidx, values, _ = random_csr(rng, 40, 40, 0.15)
    for r, c in [(1, 8), (2, 4), (4, 8)]:
        desc = csr_to_block_desc(rowptr, colidx, values, 40, 40, r=r, c=c)
        pops = sum(bin(int(m)).count("1") for m in desc.block_mask)
        assert pops == desc.nnz


def test_offsets_are_prefix_popcounts():
    rng = np.random.default_rng(9)
    rowptr, colidx, values, _ = random_csr(rng, 25, 25, 0.3)
    desc = csr_to_block_desc(rowptr, colidx, values, 25, 25, r=2, c=8)
    acc = 0
    for i in range(desc.n_padded):
        if desc.block_mask[i] != 0:
            assert desc.block_off[i] == acc
            acc += bin(int(desc.block_mask[i])).count("1")
    assert acc == desc.nnz
