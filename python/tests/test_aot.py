"""AOT lowering contract tests — guards for the HLO-text interchange.

The rust side parses HLO *text* with xla_extension 0.5.1. Two gotchas
are pinned here:

1. large dense constants must be printed in full — the default printer
   elides them as ``{...}`` and the consumer-side parser silently turns
   that into garbage (this exact bug cost a debugging session; see
   aot.to_hlo_text);
2. the entry computation must take (values, x[, ...]) as parameters
   with the shapes the manifest advertises, and return a tuple.
"""

import jax
import numpy as np

from compile.aot import to_hlo_text
from compile.kernels.ref import poisson2d_csr
from compile.kernels.spmv_block import csr_to_block_desc
from compile.model import cg_graph, spmv_graph

jax.config.update("jax_enable_x64", True)


def lower(n=8, iters=4):
    rowptr, colidx, values = poisson2d_csr(n)
    dim = n * n
    desc = csr_to_block_desc(rowptr, colidx, values, dim, dim)
    vspec = jax.ShapeDtypeStruct((desc.nnz,), np.float64)
    xspec = jax.ShapeDtypeStruct((dim,), np.float64)
    spmv_text = to_hlo_text(jax.jit(spmv_graph(desc)).lower(vspec, xspec))
    cg_text = to_hlo_text(
        jax.jit(cg_graph(desc, iters)).lower(vspec, xspec, xspec)
    )
    return desc, spmv_text, cg_text


def test_no_elided_constants():
    _, spmv_text, cg_text = lower()
    assert "{...}" not in spmv_text, "large constants must be printed"
    assert "{...}" not in cg_text


def test_entry_signature_matches_manifest_contract():
    desc, spmv_text, cg_text = lower()
    dim = desc.rows
    # ENTRY takes f64[nnz] then f64[dim].
    assert f"f64[{desc.nnz}]" in spmv_text
    assert f"f64[{dim}]" in spmv_text
    # CG takes three params (values, b, x0) and returns (x, rs).
    entry = cg_text[cg_text.rindex("ENTRY") :]
    assert entry.count("parameter(") == 3, entry[:400]


def test_hlo_text_is_parseable_header():
    _, spmv_text, _ = lower()
    assert spmv_text.lstrip().startswith("HloModule")
