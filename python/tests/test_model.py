"""L2 graph tests: CG convergence, power iteration, shape contracts."""

import jax
import jax.numpy as jnp
import numpy as np

from compile.kernels.ref import poisson2d_csr, spmv_csr_ref
from compile.kernels.spmv_block import csr_to_block_desc
from compile.model import cg_graph, power_iteration_graph, spmv_graph

jax.config.update("jax_enable_x64", True)


def poisson_desc(n):
    rowptr, colidx, values = poisson2d_csr(n)
    dim = n * n
    desc = csr_to_block_desc(rowptr, colidx, values, dim, dim, r=1, c=8)
    return desc, (rowptr, colidx, values)


def test_spmv_graph_matches_csr():
    desc, (rowptr, colidx, values) = poisson_desc(12)
    dim = 12 * 12
    rng = np.random.default_rng(3)
    x = rng.uniform(-1, 1, dim)
    f = jax.jit(spmv_graph(desc))
    (y,) = f(jnp.asarray(desc.values), jnp.asarray(x))
    want = spmv_csr_ref(rowptr, colidx, values, x)
    np.testing.assert_allclose(np.asarray(y), want, rtol=1e-12, atol=1e-12)


def test_cg_converges_on_poisson():
    n = 10
    desc, (rowptr, colidx, values) = poisson_desc(n)
    dim = n * n
    rng = np.random.default_rng(4)
    b = rng.uniform(-1, 1, dim)
    f = jax.jit(cg_graph(desc, iters=300))
    x, rs = f(jnp.asarray(desc.values), jnp.asarray(b), jnp.zeros(dim))
    # Residual must be tiny and A x ≈ b.
    assert float(rs) < 1e-16 * dim or float(rs) < 1e-10
    ax = spmv_csr_ref(rowptr, colidx, values, np.asarray(x))
    np.testing.assert_allclose(ax, b, rtol=0, atol=1e-6)


def test_cg_zero_rhs_stays_zero():
    desc, _ = poisson_desc(6)
    dim = 36
    f = jax.jit(cg_graph(desc, iters=20))
    x, rs = f(jnp.asarray(desc.values), jnp.zeros(dim), jnp.zeros(dim))
    assert float(rs) == 0.0
    np.testing.assert_array_equal(np.asarray(x), np.zeros(dim))


def test_power_iteration_dominant_eig():
    n = 8
    desc, (rowptr, colidx, values) = poisson_desc(n)
    dim = n * n
    f = jax.jit(power_iteration_graph(desc, iters=400))
    # Random start: the all-ones vector is nearly orthogonal to the
    # Laplacian's dominant (highly oscillatory) eigenvector.
    v0 = np.random.default_rng(11).uniform(-1, 1, dim)
    v, lam = f(jnp.asarray(desc.values), jnp.asarray(v0))
    # The Laplacian's top eigenvalues are clustered, so 400 steps only
    # get within a few percent directionally — check the Rayleigh
    # residual rather than exact eigenpair equality, plus the known
    # spectral range λmax = 8·sin²(nπ/(2(n+1))) < 8.
    av = spmv_csr_ref(rowptr, colidx, values, np.asarray(v))
    res = np.linalg.norm(av - float(lam) * np.asarray(v))
    assert res / float(lam) < 0.05, f"residual {res}, lambda {float(lam)}"
    lam_true = 8.0 * np.sin(n * np.pi / (2 * (n + 1))) ** 2
    assert abs(float(lam) - lam_true) < 0.05 * lam_true
    assert 4.0 < float(lam) < 8.0


def test_values_as_runtime_parameter():
    # One compiled executable, two coefficient sets (the deployment the
    # operator form exists for).
    desc, (rowptr, colidx, values) = poisson_desc(6)
    dim = 36
    f = jax.jit(spmv_graph(desc))
    x = np.ones(dim)
    (y1,) = f(jnp.asarray(desc.values), jnp.asarray(x))
    (y2,) = f(jnp.asarray(desc.values) * 2.0, jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(y2), 2.0 * np.asarray(y1), rtol=1e-12)
