"""Dump block descriptors as JSON — consumed by the Rust integration
test `cross_lang.rs` to prove the Python and Rust β conversions emit
identical streams (the property the AOT artifact path relies on: the
Rust coordinator feeds `values` in CSR order to an executable whose
descriptor constants came from the Python conversion).

Usage: python -m compile.dump --n 12
"""

from __future__ import annotations

import argparse
import json

from .kernels.ref import poisson2d_csr
from .kernels.spmv_block import csr_to_block_desc


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=12)
    ap.add_argument("--r", type=int, default=1)
    ap.add_argument("--c", type=int, default=8)
    args = ap.parse_args()

    rowptr, colidx, values = poisson2d_csr(args.n)
    dim = args.n * args.n
    desc = csr_to_block_desc(
        rowptr, colidx, values, dim, dim, r=args.r, c=args.c
    )
    print(
        json.dumps(
            {
                "rows": desc.rows,
                "cols": desc.cols,
                "c": desc.c,
                "nnz": desc.nnz,
                "block_row": desc.block_row.tolist(),
                "block_col": desc.block_col.tolist(),
                "block_mask": desc.block_mask.tolist(),
                "block_off": desc.block_off.tolist(),
            }
        )
    )


if __name__ == "__main__":
    main()
