"""L1 — Pallas kernel: block-based SpMV without zero padding (TPU rethink).

The paper's kernels rely on AVX-512 ``vexpandpd``: inflate the next
``popcnt(mask)`` packed values into the lanes selected by a bitmask.
TPUs have no expand instruction, so a mechanical port is impossible.
The TPU-shaped equivalent implemented here keeps the paper's core
insight — *store only the nonzeros, keep intra-block sparsity as one
mask word, re-inflate in registers, never in memory* — and maps each
piece to TPU-native constructs (DESIGN.md §Hardware-Adaptation):

=====================  =============================================
paper (AVX-512)        this kernel (Pallas/TPU)
=====================  =============================================
``vexpandpd`` serial   per-lane *rank* = prefix-popcount of the mask,
``idx_val += popcnt``  block *value offsets* precomputed host-side →
                       a masked gather ``values[offset + rank]``
row-interval walk      grid over fixed-size block *strips*; the
                       HBM→VMEM schedule the paper wrote with row
                       intervals is a ``BlockSpec`` over strips
masked load of x       ``where(bit, x[col0+k], 0)`` gather
per-row accumulators   strip-local segment-sum by row, accumulated
``vaddsd`` at end      into the output ref across sequential grid
                       steps
=====================  =============================================

Padding only ever touches *block descriptors* (strips are padded with
``mask = 0`` entries); the values array stays exactly the nonzeros —
the paper's "no zero padding" storage contract.

The kernel's unit of work is a **block row** (one ``(row, col0, mask,
offset)`` record). Any ``β(r,c)`` with r > 1 is flattened to block rows
host-side, so one kernel serves every paper block size.

Everything runs with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls; real-TPU efficiency is estimated in
DESIGN.md §9.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

# Blocks per grid step. Must match `STRIP` in rust/src/runtime/mod.rs —
# the Rust coordinator pads its descriptor arrays to this granularity
# before feeding the AOT artifact.
STRIP = 256


@dataclass(frozen=True)
class BlockDesc:
    """Host-side descriptor arrays of a β(r,c) matrix, flattened to
    block rows and padded to a multiple of STRIP.

    Invariant: ``offsets[i]`` is the index into ``values`` of block row
    i's first nonzero; padding entries have ``mask == 0`` and repeat the
    last offset, so they gather nothing.
    """

    rows: int
    cols: int
    c: int  # block width (bits per mask)
    block_row: np.ndarray  # [nb_pad] int32 — target row of each block row
    block_col: np.ndarray  # [nb_pad] int32 — leftmost column
    block_mask: np.ndarray  # [nb_pad] int32 — c-bit mask
    block_off: np.ndarray  # [nb_pad] int32 — prefix popcount into values
    values: np.ndarray  # [nnz] float — the nonzeros, NO padding

    @property
    def n_padded(self) -> int:
        return len(self.block_row)

    @property
    def nnz(self) -> int:
        return len(self.values)


def csr_to_block_desc(
    rowptr: np.ndarray,
    colidx: np.ndarray,
    values: np.ndarray,
    rows: int,
    cols: int,
    r: int = 1,
    c: int = 8,
    dtype=np.float64,
) -> BlockDesc:
    """CSR → β(r,c) descriptors, flattened to block rows.

    Mirrors the greedy cover of ``rust/src/formats/convert.rs`` exactly:
    inside each r-row interval, blocks anchor at the leftmost uncovered
    nonzero; values are appended block by block, row-major inside the
    block. The flattened (row, col0, mask, offset) records keep that
    value order, so the two implementations produce bit-identical
    streams (checked by an integration test).
    """
    assert 1 <= c <= 8 and r * c <= 64
    b_row: list[int] = []
    b_col: list[int] = []
    b_mask: list[int] = []
    b_off: list[int] = []
    vals: list[float] = []

    intervals = (rows + r - 1) // r
    for it in range(intervals):
        row0 = it * r
        rows_here = min(r, rows - row0)
        cursor = [int(rowptr[row0 + i]) for i in range(rows_here)]
        ends = [int(rowptr[row0 + i + 1]) for i in range(rows_here)]
        while True:
            min_col = None
            for i in range(rows_here):
                if cursor[i] < ends[i]:
                    col = int(colidx[cursor[i]])
                    if min_col is None or col < min_col:
                        min_col = col
            if min_col is None:
                break
            col_end = min_col + c
            for i in range(rows_here):
                mask = 0
                off = len(vals)
                while cursor[i] < ends[i] and int(colidx[cursor[i]]) < col_end:
                    k = cursor[i]
                    mask |= 1 << (int(colidx[k]) - min_col)
                    vals.append(float(values[k]))
                    cursor[i] += 1
                if mask != 0:
                    b_row.append(row0 + i)
                    b_col.append(min_col)
                    b_mask.append(mask)
                    b_off.append(off)

    nb = len(b_row)
    nb_pad = max(STRIP, ((nb + STRIP - 1) // STRIP) * STRIP)
    pad = nb_pad - nb
    last_off = len(vals)
    return BlockDesc(
        rows=rows,
        cols=cols,
        c=c,
        block_row=np.asarray(b_row + [0] * pad, dtype=np.int32),
        block_col=np.asarray(b_col + [0] * pad, dtype=np.int32),
        block_mask=np.asarray(b_mask + [0] * pad, dtype=np.int32),
        block_off=np.asarray(b_off + [last_off] * pad, dtype=np.int32),
        values=np.asarray(vals, dtype=dtype),
    )


def _spmv_kernel(row_ref, col_ref, mask_ref, off_ref, val_ref, x_ref, o_ref, *, c: int, rows: int):
    """Pallas kernel body: one grid step = one strip of STRIP block rows.

    The expand: for lane k of a block, ``rank_k = popcount(mask &
    ((1<<k)-1))`` ranks the set bits; ``values[offset + rank_k]``
    fetches the packed nonzero that lane k would have received from
    ``vexpandpd``; lanes with a clear bit contribute zero without
    touching memory semantics (gather index is clamped in-bounds).
    """
    step = pl.program_id(0)

    # Strip-local descriptor slices (VMEM-resident per BlockSpec).
    rowv = row_ref[...]
    colv = col_ref[...]
    maskv = mask_ref[...]
    offv = off_ref[...]

    # lanes [STRIP, c]
    lane = jnp.arange(c, dtype=jnp.int32)[None, :]
    bits = (maskv[:, None] >> lane) & 1  # 1 where the block holds a value
    below = maskv[:, None] & ((1 << lane) - 1)
    # prefix popcount per lane (rank of the value inside the block)
    rank = jax.lax.population_count(below.astype(jnp.uint32)).astype(jnp.int32)

    nnz = val_ref.shape[0]
    vidx = jnp.clip(offv[:, None] + rank, 0, nnz - 1)
    gathered = val_ref[vidx]  # [STRIP, c]
    xcols = jnp.clip(colv[:, None] + lane, 0, x_ref.shape[0] - 1)
    xg = x_ref[xcols]
    contrib = jnp.where(bits == 1, gathered * xg, 0.0)
    partial = jnp.sum(contrib, axis=1)  # [STRIP]

    # Segment-sum by target row (padding rows carry mask 0 → contribute 0).
    y_update = jnp.zeros((rows,), dtype=o_ref.dtype).at[rowv].add(partial)

    # Sequential grid: initialize on the first step, accumulate after.
    @pl.when(step == 0)
    def _init():
        o_ref[...] = y_update

    @pl.when(step != 0)
    def _acc():
        o_ref[...] = o_ref[...] + y_update


def spmv(desc: BlockDesc, x: jax.Array) -> jax.Array:
    """``y = A @ x`` for a matrix in block-descriptor form.

    Jittable; lowers to a single pallas_call with a grid over strips.
    """
    nb = desc.n_padded
    assert nb % STRIP == 0
    grid = nb // STRIP
    dtype = desc.values.dtype
    if desc.nnz == 0:
        # Degenerate empty matrix: nothing to gather (and a 0-length
        # operand cannot be indexed), the product is identically zero.
        return jnp.zeros((desc.rows,), dtype=dtype)
    kernel = functools.partial(_spmv_kernel, c=desc.c, rows=desc.rows)
    strip_spec = pl.BlockSpec((STRIP,), lambda i: (i,))
    full = lambda shape: pl.BlockSpec(shape, lambda i: tuple(0 for _ in shape))
    return pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[
            strip_spec,  # block_row
            strip_spec,  # block_col
            strip_spec,  # block_mask
            strip_spec,  # block_off
            full((desc.nnz,)),  # values
            full((desc.cols,)),  # x
        ],
        out_specs=full((desc.rows,)),
        out_shape=jax.ShapeDtypeStruct((desc.rows,), dtype),
        interpret=True,
    )(
        jnp.asarray(desc.block_row),
        jnp.asarray(desc.block_col),
        jnp.asarray(desc.block_mask),
        jnp.asarray(desc.block_off),
        jnp.asarray(desc.values),
        x.astype(dtype),
    )


def spmv_operator(desc: BlockDesc):
    """Returns a jit-compatible ``matvec(values, x)`` closure over the
    static descriptor arrays — the form L2 (model.py) composes into CG.

    ``values`` is a runtime argument so one compiled executable serves
    any matrix with the same sparsity structure (the classic iterative-
    solver deployment: structure fixed, coefficients change).
    """
    assert desc.nnz > 0, "AOT operator needs a non-empty matrix"
    row = jnp.asarray(desc.block_row)
    col = jnp.asarray(desc.block_col)
    mask = jnp.asarray(desc.block_mask)
    off = jnp.asarray(desc.block_off)
    nb = desc.n_padded
    grid = nb // STRIP
    kernel = functools.partial(_spmv_kernel, c=desc.c, rows=desc.rows)
    strip_spec = pl.BlockSpec((STRIP,), lambda i: (i,))
    full = lambda shape: pl.BlockSpec(shape, lambda i: tuple(0 for _ in shape))

    def matvec(values: jax.Array, x: jax.Array) -> jax.Array:
        return pl.pallas_call(
            kernel,
            grid=(grid,),
            in_specs=[
                strip_spec,
                strip_spec,
                strip_spec,
                strip_spec,
                full((desc.nnz,)),
                full((desc.cols,)),
            ],
            out_specs=full((desc.rows,)),
            out_shape=jax.ShapeDtypeStruct((desc.rows,), values.dtype),
            interpret=True,
        )(row, col, mask, off, values, x)

    return matvec
