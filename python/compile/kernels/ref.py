"""Pure-jnp / numpy oracles for the Pallas kernel.

Two independent references:

- :func:`spmv_csr_ref` — SpMV straight from CSR (numpy, no jax), the
  semantic ground truth;
- :func:`spmv_desc_ref` — SpMV from the block descriptors with plain
  jnp ops (no pallas), catching conversion bugs separately from kernel
  bugs.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .spmv_block import BlockDesc


def spmv_csr_ref(
    rowptr: np.ndarray, colidx: np.ndarray, values: np.ndarray, x: np.ndarray
) -> np.ndarray:
    """Dense-semantics SpMV from CSR."""
    rows = len(rowptr) - 1
    y = np.zeros(rows, dtype=np.result_type(values.dtype, x.dtype))
    for r in range(rows):
        a, b = int(rowptr[r]), int(rowptr[r + 1])
        if a != b:
            y[r] = np.dot(values[a:b], x[colidx[a:b]])
    return y


def spmv_desc_ref(desc: BlockDesc, x) -> jnp.ndarray:
    """SpMV from block descriptors with vectorized jnp (no pallas)."""
    if desc.nnz == 0:
        return jnp.zeros((desc.rows,), dtype=desc.values.dtype)
    lane = np.arange(desc.c, dtype=np.int64)[None, :]
    mask = np.asarray(desc.block_mask, dtype=np.int64)[:, None]
    bits = (mask >> lane) & 1
    below = mask & ((1 << lane) - 1)
    # prefix popcount, numpy-side (oracle may be slow, that is fine)
    rank = np.zeros_like(below)
    for k in range(desc.c):
        rank += (below >> k) & 1
    vidx = np.clip(np.asarray(desc.block_off)[:, None] + rank, 0, desc.nnz - 1)
    xcols = np.clip(
        np.asarray(desc.block_col)[:, None] + lane, 0, desc.cols - 1
    )
    vals = np.asarray(desc.values)[vidx]
    xg = np.asarray(x)[xcols]
    contrib = np.where(bits == 1, vals * xg, 0.0)
    partial = contrib.sum(axis=1)
    y = np.zeros((desc.rows,), dtype=desc.values.dtype)
    np.add.at(y, np.asarray(desc.block_row), partial)
    return jnp.asarray(y)


def random_csr(
    rng: np.random.Generator,
    rows: int,
    cols: int,
    density: float,
    dtype=np.float64,
):
    """Deterministic random CSR for tests; returns (rowptr, colidx,
    values, dense)."""
    mask = rng.random((rows, cols)) < density
    dense = np.where(mask, rng.uniform(-1.0, 1.0, (rows, cols)), 0.0).astype(
        dtype
    )
    rowptr = np.zeros(rows + 1, dtype=np.int32)
    colidx, values = [], []
    for r in range(rows):
        nz = np.nonzero(dense[r])[0]
        rowptr[r + 1] = rowptr[r] + len(nz)
        colidx.extend(nz.tolist())
        values.extend(dense[r, nz].tolist())
    return (
        rowptr,
        np.asarray(colidx, dtype=np.int32),
        np.asarray(values, dtype=dtype),
        dense,
    )


def poisson2d_csr(n: int, dtype=np.float64):
    """The same 2D 5-point Laplacian as rust `matrix::suite::poisson2d`
    (row-major grid ordering, ascending columns per row) — the shared
    workload of the AOT artifacts."""
    dim = n * n
    rowptr = np.zeros(dim + 1, dtype=np.int32)
    colidx, values = [], []
    for y in range(n):
        for x in range(n):
            r = y * n + x
            ents = [(r, 4.0)]
            if x > 0:
                ents.append((r - 1, -1.0))
            if x + 1 < n:
                ents.append((r + 1, -1.0))
            if y > 0:
                ents.append((r - n, -1.0))
            if y + 1 < n:
                ents.append((r + n, -1.0))
            ents.sort()
            rowptr[r + 1] = rowptr[r] + len(ents)
            for c, v in ents:
                colidx.append(c)
                values.append(v)
    return (
        rowptr,
        np.asarray(colidx, dtype=np.int32),
        np.asarray(values, dtype=dtype),
    )
