"""L2 — the JAX compute graphs built on the L1 Pallas kernel.

Two graphs, both AOT-lowered by :mod:`compile.aot` and executed from
the Rust coordinator:

- :func:`spmv_graph` — a single SpMV ``y = A·x`` (the paper's hot
  operation);
- :func:`cg_graph` — ``iters`` steps of the conjugate-gradient method
  (the paper's motivating application: "iterative solvers based on
  Krylov subspaces, such as the popular CG method"), with the Pallas
  SpMV as the only matrix touch-point. Lowered with a
  ``lax.fori_loop`` so the whole solve is ONE executable — no
  host↔device round-trip per iteration.

The matrix *structure* (block descriptors) is compile-time constant;
``values`` and the vectors are runtime parameters, so one artifact
serves every matrix with that sparsity pattern.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.spmv_block import BlockDesc, spmv_operator


def spmv_graph(desc: BlockDesc):
    """Returns ``f(values, x) -> (y,)`` for AOT lowering."""
    matvec = spmv_operator(desc)

    def f(values, x):
        return (matvec(values, x),)

    return f


def cg_graph(desc: BlockDesc, iters: int):
    """Returns ``f(values, b, x0) -> (x, r_norm2)`` running `iters` CG
    steps on the SPD system ``A x = b``.

    Classic (unpreconditioned) CG; every iteration's single SpMV goes
    through the Pallas kernel. The final squared residual norm comes
    back with the solution so the caller can verify convergence without
    a second artifact.
    """
    assert desc.rows == desc.cols, "CG needs a square (SPD) matrix"
    matvec = spmv_operator(desc)

    def f(values, b, x0):
        r0 = b - matvec(values, x0)
        p0 = r0
        rs0 = jnp.dot(r0, r0)

        def step(_, state):
            x, r, p, rs = state
            ap = matvec(values, p)
            denom = jnp.dot(p, ap)
            alpha = jnp.where(denom != 0.0, rs / denom, 0.0)
            x = x + alpha * p
            r = r - alpha * ap
            rs_new = jnp.dot(r, r)
            beta = jnp.where(rs != 0.0, rs_new / rs, 0.0)
            p = r + beta * p
            return (x, r, p, rs_new)

        x, r, _, rs = jax.lax.fori_loop(0, iters, step, (x0, r0, p0, rs0))
        del r
        return (x, rs)

    return f


def power_iteration_graph(desc: BlockDesc, iters: int):
    """Returns ``f(values, v0) -> (v, lambda)`` — `iters` power-method
    steps estimating the dominant eigenpair; a second, cheaper L2
    consumer of the kernel used by the spmv_server example."""
    assert desc.rows == desc.cols
    matvec = spmv_operator(desc)

    def f(values, v0):
        def step(_, v):
            w = matvec(values, v)
            return w / jnp.linalg.norm(w)

        v = jax.lax.fori_loop(0, iters, step, v0 / jnp.linalg.norm(v0))
        lam = jnp.dot(v, matvec(values, v))
        return (v, lam)

    return f
