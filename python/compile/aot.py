"""AOT compile path: lower the L2 graphs to HLO **text** artifacts.

HLO text (not ``.serialize()``): jax ≥ 0.5 emits HloModuleProto with
64-bit instruction ids that the rust side's xla_extension 0.5.1
rejects; the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Artifacts (under ``--out-dir``, default ``../artifacts``):

- ``spmv_poisson{N}.hlo.txt``  — one SpMV on the 2D Poisson N×N grid
  matrix in β(1,8) descriptors;
- ``cg_poisson{N}_it{K}.hlo.txt`` — K CG iterations on the same system;
- ``power_poisson{N}_it{K}.hlo.txt`` — K power-method steps;
- ``manifest.json`` — shapes the Rust runtime validates against before
  executing (rows, cols, nnz, padded block count, strip size).

Python runs ONCE (`make artifacts`); nothing here is on the request
path.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from .kernels.ref import poisson2d_csr
from .kernels.spmv_block import STRIP, csr_to_block_desc
from .model import cg_graph, power_iteration_graph, spmv_graph

jax.config.update("jax_enable_x64", True)

# Workload parameters shared with the Rust examples (examples/cg_solver.rs).
POISSON_N = 64
CG_ITERS = 200
POWER_ITERS = 50
DTYPE = np.float64


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the interchange format).

    ``print_large_constants=True`` is essential: the default printer
    elides big dense constants as ``{...}``, which the consumer-side
    text parser (xla_extension 0.5.1) silently turns into garbage —
    the block-descriptor arrays baked into the kernel are exactly such
    constants.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def lower_and_write(fn, args, path: str) -> None:
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {path} ({len(text)} chars)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--n", type=int, default=POISSON_N)
    ap.add_argument("--cg-iters", type=int, default=CG_ITERS)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    n = args.n
    rowptr, colidx, values = poisson2d_csr(n, dtype=DTYPE)
    dim = n * n
    desc = csr_to_block_desc(
        rowptr, colidx, values, dim, dim, r=1, c=8, dtype=DTYPE
    )
    print(
        f"poisson {n}x{n}: dim={dim} nnz={desc.nnz} "
        f"blocks_padded={desc.n_padded} strip={STRIP}"
    )

    vspec = jax.ShapeDtypeStruct((desc.nnz,), DTYPE)
    xspec = jax.ShapeDtypeStruct((dim,), DTYPE)

    spmv_name = f"spmv_poisson{n}.hlo.txt"
    lower_and_write(
        spmv_graph(desc), (vspec, xspec), os.path.join(args.out_dir, spmv_name)
    )

    cg_name = f"cg_poisson{n}_it{args.cg_iters}.hlo.txt"
    lower_and_write(
        cg_graph(desc, args.cg_iters),
        (vspec, xspec, xspec),
        os.path.join(args.out_dir, cg_name),
    )

    power_name = f"power_poisson{n}_it{POWER_ITERS}.hlo.txt"
    lower_and_write(
        power_iteration_graph(desc, POWER_ITERS),
        (vspec, xspec),
        os.path.join(args.out_dir, power_name),
    )

    manifest = {
        "version": 1,
        "strip": STRIP,
        "workloads": {
            "spmv": {
                "file": spmv_name,
                "n": n,
                "rows": dim,
                "cols": dim,
                "nnz": int(desc.nnz),
                "blocks_padded": int(desc.n_padded),
                "params": ["values[nnz]", "x[cols]"],
                "outputs": ["y[rows]"],
            },
            "cg": {
                "file": cg_name,
                "n": n,
                "rows": dim,
                "cols": dim,
                "nnz": int(desc.nnz),
                "iters": args.cg_iters,
                "params": ["values[nnz]", "b[rows]", "x0[rows]"],
                "outputs": ["x[rows]", "r_norm2[]"],
            },
            "power": {
                "file": power_name,
                "n": n,
                "rows": dim,
                "cols": dim,
                "nnz": int(desc.nnz),
                "iters": POWER_ITERS,
                "params": ["values[nnz]", "v0[rows]"],
                "outputs": ["v[rows]", "lambda[]"],
            },
        },
    }
    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
